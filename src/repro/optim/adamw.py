"""Optimizers in pure JAX: AdamW (default) and Adafactor (factored second
moment) for giant expert/embedding matrices.

A per-leaf policy keeps trillion-parameter MoE states in budget: 3-D expert
stacks (E, d_in, d_out) can be switched to Adafactor (no first moment, rank-1
second moment), which is what makes kimi-k2 (1T params) fit 512 × 16 GB HBM —
see DESIGN.md §6.  State dtype is configurable (bf16 moments for the MoE
giants, f32 elsewhere).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moments_dtype: str = "float32"
    factored_experts: bool = False   # Adafactor for (E, din, dout) leaves


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    import math
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(math.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def _is_factored(path, leaf) -> bool:
    return leaf.ndim == 3 and any(
        getattr(k, "key", None) == "experts" for k in path)


class OptState(NamedTuple):
    step: jax.Array
    m: Pytree            # first moment (None-leaves where factored)
    v: Pytree            # second moment (or (row, col) tuples where factored)


def init_opt_state(cfg: OptimizerConfig, params: Pytree) -> OptState:
    mdt = jnp.dtype(cfg.moments_dtype)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)

    ms, vs = [], []
    for path, leaf in flat:
        if cfg.factored_experts and _is_factored(path, leaf):
            ms.append(jnp.zeros((), mdt))            # placeholder (no m)
            vs.append((jnp.zeros(leaf.shape[:-1], mdt),      # row stats
                       jnp.zeros(leaf.shape[:-2] + leaf.shape[-1:], mdt)))
        else:
            ms.append(jnp.zeros(leaf.shape, mdt))
            vs.append(jnp.zeros(leaf.shape, mdt))
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree_util.tree_unflatten(treedef, ms),
                    v=jax.tree_util.tree_unflatten(treedef, vs))


def _global_norm(grads: Pytree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(g.astype(jnp.float32) ** 2)
        for g in jax.tree_util.tree_leaves(grads)))


def apply_updates(cfg: OptimizerConfig, params: Pytree, grads: Pytree,
                  state: OptState) -> tuple[Pytree, OptState, dict]:
    """One optimizer step.  Returns (params, state, metrics)."""
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)
    b1, b2 = cfg.betas
    corr1 = 1 - b1 ** step.astype(jnp.float32)
    corr2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moments_dtype)

    pflat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    gleaves = jax.tree_util.tree_leaves(grads)
    mleaves = jax.tree_util.tree_leaves(
        state.m, is_leaf=lambda x: isinstance(x, jnp.ndarray))
    # v may contain tuples → flatten against params structure
    vflat = jax.tree_util.tree_flatten(
        state.v, is_leaf=lambda x: isinstance(x, (tuple, jnp.ndarray)))[0]

    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(pflat, gleaves, mleaves, vflat):
        gf = (g.astype(jnp.float32) * scale)
        if cfg.factored_experts and _is_factored(path, p):
            vr, vc = v
            g2 = gf * gf + 1e-30
            nvr = b2 * vr.astype(jnp.float32) + (1 - b2) * g2.mean(axis=-1)
            nvc = b2 * vc.astype(jnp.float32) + (1 - b2) * g2.mean(axis=-2)
            # rank-1 reconstruction of v̂
            denom = nvr[..., :, None] * nvc[..., None, :] / jnp.maximum(
                nvr.mean(axis=-1)[..., None, None], 1e-30)
            upd = gf / (jnp.sqrt(denom / corr2) + cfg.eps)
            new_m.append(m)          # unused placeholder
            new_v.append((nvr.astype(mdt), nvc.astype(mdt)))
        else:
            nm = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            nv = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            upd = (nm / corr1) / (jnp.sqrt(nv / corr2) + cfg.eps)
            new_m.append(nm.astype(mdt))
            new_v.append(nv.astype(mdt))
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        pf = p.astype(jnp.float32)
        pf = pf - lr * (upd + decay * pf)
        new_p.append(pf.astype(p.dtype))

    params = jax.tree_util.tree_unflatten(treedef, new_p)
    m_tree = jax.tree_util.tree_unflatten(treedef, new_m)
    v_tree = jax.tree_util.tree_unflatten(treedef, new_v)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return params, OptState(step=step, m=m_tree, v=v_tree), metrics
