"""Spec linter: estimate a search space's statically-infeasible fraction.

``python -m repro.analysis.lint spec.json`` loads a :class:`TuningSpec`,
samples schedules from its search space, runs the static analyzer configured
for the spec's backend (no measurements — the backend is constructed only to
read its red-node knobs), and prints the infeasible fraction plus a per-rule
histogram.  Run it before submitting a job to the fleet: a space dominated by
one rule's red nodes is usually a mis-specified space, and the fraction bounds
how much `static_analysis=True` can save.

The same check is exposed as a callable API — :func:`lint_spec` — which the
fleet dispatcher (:mod:`repro.fleet.server`) runs at the door on every
submitted spec: a spec that cannot even resolve, or whose sampled space is
*entirely* statically infeasible, is rejected with a typed error instead of
burning a measurement worker on it.

Exit codes: 0 = report printed, 2 = bad spec (unreadable / unresolvable),
matching the session CLI's convention.
"""

from __future__ import annotations

import argparse
from typing import Sequence

__all__ = ["LintError", "lint_spec", "main"]


class LintError(ValueError):
    """A spec that fails the door lint, carrying a typed machine-readable
    reason (``code``) alongside the human-readable message.

    Codes: ``"bad-spec"`` — the document does not resolve to a runnable job
    (unknown workload/backend/strategy, malformed args); ``"infeasible-space"``
    — the spec resolves but every sampled schedule is statically red, so
    dispatching it would only burn a worker producing red nodes.
    """

    def __init__(self, code: str, detail: str, report: dict | None = None):
        super().__init__(detail)
        self.code = code
        self.detail = detail
        self.report = report or {}

    def to_dict(self) -> dict:
        return {"error": self.code, "detail": self.detail,
                "report": self.report}


def lint_spec(spec, samples: int = 1000, seed: int = 0,
              max_depth: int = 4) -> dict:
    """Statically lint one :class:`~repro.core.session.TuningSpec` (instance
    or plain dict): resolve it, sample ``samples`` schedules from its search
    space, and run the static analyzer configured for its backend — zero
    measurements.

    Returns the report dict ``{"workload", "backend_model", "samples",
    "seed", "passes", "infeasible", "infeasible_fraction", "by_rule"}``.
    Raises :class:`LintError` with ``code="bad-spec"`` when the spec does not
    resolve, and ``code="infeasible-space"`` when *every* sampled schedule is
    statically infeasible (sampling found nothing a backend would measure).
    """
    from repro.core.session import TuningSpec

    try:
        if not isinstance(spec, TuningSpec):
            spec = TuningSpec.from_dict(spec)
        workload = spec.build_workload()
        space = spec.build_space(workload)
        backend = spec.build_backend()
        spec.build_peers()
    except (OSError, ValueError, TypeError, KeyError) as e:
        raise LintError("bad-spec", str(e)) from e

    from .differential import sample_configs
    from .passes import StaticAnalyzer

    analyzer = StaticAnalyzer(workload, backend=backend)
    configs = sample_configs(space, samples, seed=seed, max_depth=max_depth)
    by_rule: dict[str, int] = {}
    infeasible = 0
    for config in configs:
        nest = space.try_structure(config)
        v = analyzer.analyze(nest, config=config)
        if not v.feasible:
            infeasible += 1
            by_rule[v.rule] = by_rule.get(v.rule, 0) + 1

    n = len(configs)
    report = {
        "workload": getattr(workload, "name", "?"),
        "backend_model": analyzer.model.kind,
        "samples": n,
        "seed": seed,
        "passes": list(analyzer.passes),
        "infeasible": infeasible,
        "infeasible_fraction": infeasible / n if n else 0.0,
        "by_rule": dict(sorted(by_rule.items(),
                               key=lambda kv: (-kv[1], kv[0]))),
    }
    if n and infeasible == n:
        raise LintError(
            "infeasible-space",
            f"all {n} sampled schedules are statically infeasible "
            f"(rules: {', '.join(report['by_rule'])})",
            report)
    return report


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Statically lint a TuningSpec's search space: sampled "
                    "infeasible fraction + per-rule histogram, no "
                    "measurements.")
    ap.add_argument("spec", help="path to a TuningSpec JSON document")
    ap.add_argument("--samples", type=int, default=1000,
                    help="schedules to sample (default 1000)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-depth", type=int, default=4,
                    help="random-walk depth cap (default 4)")
    args = ap.parse_args(argv)

    from repro.core.session import TuningSpec

    try:
        spec = TuningSpec.load(args.spec)
    except (OSError, ValueError, TypeError) as e:
        print(f"error: bad spec: {e}")
        return 2
    try:
        report = lint_spec(spec, samples=args.samples, seed=args.seed,
                           max_depth=args.max_depth)
    except LintError as e:
        if e.code == "bad-spec":
            print(f"error: bad spec: {e.detail}")
            return 2
        # infeasible-space: still a report — print it like the healthy path
        report = e.report

    print(f"lint: workload={report['workload']} "
          f"backend={report['backend_model']} samples={report['samples']} "
          f"seed={report['seed']} passes={','.join(report['passes'])}")
    print(f"infeasible_fraction={report['infeasible_fraction']:.4f}")
    print(f"infeasible={report['infeasible']}")
    print("rule,count")
    for rule, count in report["by_rule"].items():
        print(f"{rule},{count}")
    return 0


if __name__ == "__main__":
    # Run through the canonical import so registry state (analysis passes)
    # is shared with library users — mirrors repro.core.session's pattern.
    from repro.analysis.lint import main as _canonical_main

    raise SystemExit(_canonical_main())
