"""Spec linter: estimate a search space's statically-infeasible fraction.

``python -m repro.analysis.lint spec.json`` loads a :class:`TuningSpec`,
samples schedules from its search space, runs the static analyzer configured
for the spec's backend (no measurements — the backend is constructed only to
read its red-node knobs), and prints the infeasible fraction plus a per-rule
histogram.  Run it before submitting a job to the fleet: a space dominated by
one rule's red nodes is usually a mis-specified space, and the fraction bounds
how much `static_analysis=True` can save.

Exit codes: 0 = report printed, 2 = bad spec (unreadable / unresolvable),
matching the session CLI's convention.
"""

from __future__ import annotations

import argparse
from typing import Sequence

__all__ = ["main"]


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Statically lint a TuningSpec's search space: sampled "
                    "infeasible fraction + per-rule histogram, no "
                    "measurements.")
    ap.add_argument("spec", help="path to a TuningSpec JSON document")
    ap.add_argument("--samples", type=int, default=1000,
                    help="schedules to sample (default 1000)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-depth", type=int, default=4,
                    help="random-walk depth cap (default 4)")
    args = ap.parse_args(argv)

    from repro.core.session import TuningSpec

    try:
        spec = TuningSpec.load(args.spec)
        workload = spec.build_workload()
        space = spec.build_space(workload)
        backend = spec.build_backend()
    except (OSError, ValueError, TypeError, KeyError) as e:
        print(f"error: bad spec: {e}")
        return 2

    from .differential import sample_configs
    from .passes import StaticAnalyzer

    analyzer = StaticAnalyzer(workload, backend=backend)
    configs = sample_configs(space, args.samples, seed=args.seed,
                             max_depth=args.max_depth)
    by_rule: dict[str, int] = {}
    infeasible = 0
    for config in configs:
        nest = space.try_structure(config)
        v = analyzer.analyze(nest, config=config)
        if not v.feasible:
            infeasible += 1
            by_rule[v.rule] = by_rule.get(v.rule, 0) + 1

    n = len(configs)
    frac = infeasible / n if n else 0.0
    print(f"lint: workload={getattr(workload, 'name', '?')} "
          f"backend={analyzer.model.kind} samples={n} seed={args.seed} "
          f"passes={','.join(analyzer.passes)}")
    print(f"infeasible_fraction={frac:.4f}")
    print(f"infeasible={infeasible}")
    print("rule,count")
    for rule, count in sorted(by_rule.items(), key=lambda kv: (-kv[1], kv[0])):
        print(f"{rule},{count}")
    return 0


if __name__ == "__main__":
    # Run through the canonical import so registry state (analysis passes)
    # is shared with library users — mirrors repro.core.session's pattern.
    from repro.analysis.lint import main as _canonical_main

    raise SystemExit(_canonical_main())
