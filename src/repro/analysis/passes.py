"""Pass-manager core: named analysis passes → :class:`Verdict` with provenance.

Each pass is a function ``(ctx: AnalysisContext) -> iterable[Finding]``
registered by name.  :class:`StaticAnalyzer` selects the passes that apply to
a (workload, backend) pair and runs them over a transformed nest; an empty
finding list means *statically feasible* (the backend may still reject it —
coverage is measured by the differential harness, soundness is the invariant).

Two pass families:

* ``dependence.*`` — legality from the dependence evidence of
  :mod:`repro.analysis.deps`.  These must be exactly equivalent to
  ``repro.core.legality.check_legal`` (the hand-coded oracle): every backend
  runs ``check_legal`` before measuring, so equivalence gives soundness for
  free and the differential harness checks it pass-by-pass.
* ``feasibility.*`` — static mirrors of the backends' *deterministic*
  ``CodegenError`` red-node conditions: plan extraction (tiling a floor
  loop), the wallclock grid-step budget on the *scaled* nest, VMEM capacity
  vs the Pallas budget, kernel expressibility (stacked tilings, reordered
  grids, head-dim tiles), and the reduced-scale verification retiling
  (non-dividing spans after tile clamping).  Each mirror calls the *same*
  production helpers (``codegen.vmem_bytes``, ``_extract_plan``,
  ``_retile_to``, ``kernel_params``) so the prediction cannot drift from the
  backend it models.

Soundness rule for every pass: reject only what the modeled backend
*deterministically* rejects.  Never predict nondeterministic failures
(timeouts, interpret/oracle mismatches) — those stay measured.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable

from repro.core import codegen
from repro.core.codegen import MAX_WALLCLOCK_GRID_STEPS
from repro.core.loopnest import LoopNest
from repro.core.measure import _is_kernel_workload, _retile_to
from repro.core.transformations import TransformError

from .deps import Dependence, dependences

__all__ = [
    "AnalysisContext",
    "BackendModel",
    "Finding",
    "StaticAnalyzer",
    "Verdict",
    "available_passes",
    "register_pass",
]


@dataclass(frozen=True)
class Finding:
    """One rule violation: which rule fired, with what evidence, and the
    :class:`~repro.core.measure.Result` status the modeled backend would
    report for it."""

    rule: str            # registered pass name that produced it
    status: str          # "illegal" | "compile_error"
    detail: str          # human-readable reason (mirrors the backend's note)
    evidence: tuple = () # Dependences / numbers backing the verdict


@dataclass(frozen=True)
class Verdict:
    """Outcome of running the selected passes over one nest."""

    feasible: bool
    findings: tuple[Finding, ...] = ()
    passes_run: tuple[str, ...] = ()

    @property
    def rule(self) -> str | None:
        return self.findings[0].rule if self.findings else None

    @property
    def status(self) -> str | None:
        return self.findings[0].status if self.findings else None

    @property
    def detail(self) -> str | None:
        return self.findings[0].detail if self.findings else None


@dataclass(frozen=True)
class BackendModel:
    """The static view of a measurement backend: just the knobs that decide
    its deterministic red nodes.  ``of`` unwraps fault-injection wrappers —
    injection never turns a red result green, so the inner backend's
    deterministic conditions survive wrapping."""

    kind: str                       # "costmodel" | "wallclock" | "pallas" | "generic"
    scale: float = 1.0
    vmem_limit: int | None = None
    verify: bool = False

    @classmethod
    def of(cls, backend) -> "BackendModel":
        b = backend
        seen = 0
        while getattr(b, "inner", None) is not None and seen < 8:
            b = b.inner
            seen += 1
        kind = getattr(b, "name", "generic")
        if kind == "costmodel":
            return cls(kind="costmodel")
        if kind == "wallclock":
            return cls(kind="wallclock", scale=getattr(b, "scale", 0.25))
        if kind == "pallas":
            return cls(
                kind="pallas",
                scale=getattr(b, "scale", 0.05),
                vmem_limit=getattr(b, "vmem_limit", 128 * 1024 * 1024),
                verify=getattr(b, "verify", True),
            )
        return cls(kind="generic")


@dataclass
class AnalysisContext:
    """Everything a pass may look at.  ``config`` is optional — backend
    mirrors that replay the schedule against scaled extents (wallclock) need
    it; dependence passes only read ``nest``."""

    workload: object
    nest: LoopNest
    config: object | None = None
    backend: BackendModel = field(default_factory=lambda: BackendModel("generic"))
    _deps: tuple[Dependence, ...] | None = None

    @property
    def deps(self) -> tuple[Dependence, ...]:
        if self._deps is None:
            self._deps = dependences(self.nest)
        return self._deps


PassFn = Callable[[AnalysisContext], Iterable[Finding]]
_PASSES: dict[str, PassFn] = {}


def register_pass(name: str) -> Callable[[PassFn], PassFn]:
    def deco(fn: PassFn) -> PassFn:
        if name in _PASSES:
            raise ValueError(f"analysis pass {name!r} already registered")
        _PASSES[name] = fn
        return fn
    return deco


def available_passes() -> tuple[str, ...]:
    return tuple(sorted(_PASSES))


# ---------------------------------------------------------------------------
# Dependence passes (legality — must match check_legal exactly)
# ---------------------------------------------------------------------------


@register_pass("dependence.parallel-reduction")
def _parallel_reduction(ctx: AnalysisContext) -> Iterable[Finding]:
    """A thread-parallelized loop must not carry a reduction dependence
    (check_legal rule 1, from dependence evidence)."""
    carried = {d.var: d for d in ctx.deps if d.kind == "reduction"}
    for l in ctx.nest.loops:
        if l.parallel and l.origin in carried:
            d = carried[l.origin]
            yield Finding(
                rule="dependence.parallel-reduction",
                status="illegal",
                detail=(f"loop {l.name} (origin {l.origin}) carries "
                        f"{d.describe()} and cannot be thread-parallelized"),
                evidence=(d,),
            )


@register_pass("dependence.triangular")
def _triangular(ctx: AnalysisContext) -> Iterable[Finding]:
    """Bound-dependence rules for triangular pairs (check_legal rules 2a–2c,
    from the structural relation of the pair's transformed loops)."""
    nest = ctx.nest
    order = [l.name for l in nest.loops]
    for d in ctx.deps:
        if d.kind != "bound":
            continue
        provider, dependent = d.provider, d.var
        prov = [l for l in nest.loops if l.origin == provider]
        dep = [l for l in nest.loops if l.origin == dependent]
        # 2a: bound exchange needs skewing.
        if order.index(dep[0].name) < order.index(prov[0].name):
            yield Finding(
                rule="dependence.triangular",
                status="illegal",
                detail=(f"{d.describe()}: loop of {dependent!r} ordered "
                        f"before its bound provider (needs loop skewing)"),
                evidence=(d, "order"),
            )
            continue
        # 2b: dependent point loop hoisted above a provider floor loop.
        prov_floor_last = max(
            (order.index(l.name) for l in prov if not l.is_point), default=-1)
        dep_point_first = min(
            (order.index(l.name) for l in dep if l.is_point), default=len(order))
        if dep_point_first < prov_floor_last:
            yield Finding(
                rule="dependence.triangular",
                status="illegal",
                detail=(f"{d.describe()}: point loop of {dependent!r} hoisted "
                        f"above a floor loop of {provider!r}"),
                evidence=(d, "hoist"),
            )
            continue
        # 2c: tiling balance across the pair, aligned level by level; the
        # dependent must not be tiled wider, alone, or deeper than its
        # provider (unmatched inner levels have no bounding tile).
        prov_pts = [l.trips for l in prov if l.is_point]
        dep_pts = [l.trips for l in dep if l.is_point]
        bad = None
        for ps, ds in zip(prov_pts, dep_pts):
            if ds > ps:
                bad = f"tile {ds} wider than provider tile {ps}"
                break
        if bad is None and dep_pts and not prov_pts:
            bad = "tiled while its bound provider is not"
        if bad is None and len(dep_pts) > len(prov_pts) > 0:
            bad = (f"tiled {len(dep_pts)}× vs provider {len(prov_pts)}× — "
                   f"unmatched inner level(s) have no bounding tile")
        if bad is not None:
            yield Finding(
                rule="dependence.triangular",
                status="illegal",
                detail=f"{d.describe()}: {dependent!r} {bad}",
                evidence=(d, tuple(prov_pts), tuple(dep_pts)),
            )


# ---------------------------------------------------------------------------
# Feasibility passes (backend mirrors)
# ---------------------------------------------------------------------------


@register_pass("feasibility.xla")
def _xla(ctx: AnalysisContext) -> Iterable[Finding]:
    """Mirror of the wallclock backend's deterministic pipeline.  The backend
    ignores nest hints and re-derives the schedule against *scaled* extents
    (``WallclockBackend.evaluate``), so the mirror must too: a tile can
    exceed a scaled extent (TransformError) or the scaled grid can bust the
    step budget even when the full-scale nest would not — and vice versa."""
    if ctx.config is None:
        return
    w = ctx.workload.scaled(ctx.backend.scale)
    try:
        nest_s = ctx.config.apply(w.nest())
    except TransformError as e:
        yield Finding(
            rule="feasibility.xla", status="compile_error",
            detail=f"schedule does not derive at scale {ctx.backend.scale}: {e}",
            evidence=(ctx.backend.scale,),
        )
        return
    try:
        plan = codegen._extract_plan(w, nest_s)
    except codegen.CodegenError as e:
        yield Finding(
            rule="feasibility.xla", status="compile_error",
            detail=str(e), evidence=("plan",),
        )
        return
    grid_steps = 1
    for _v, trips, _span in plan.grid:
        grid_steps *= trips
    if grid_steps > MAX_WALLCLOCK_GRID_STEPS:
        yield Finding(
            rule="feasibility.xla", status="compile_error",
            detail=(f"grid of {grid_steps} steps exceeds wallclock budget "
                    f"({MAX_WALLCLOCK_GRID_STEPS})"),
            evidence=(grid_steps,),
        )


@register_pass("feasibility.pallas")
def _pallas(ctx: AnalysisContext) -> Iterable[Finding]:
    """Mirror of ``PallasBackend._measure`` for einsum workloads: plan
    extraction + VMEM budget, and — when the backend verifies — the
    reduced-scale retiling's BlockSpec constraints (tile clamping can make a
    floor span stop dividing by its block width)."""
    w, nest, model = ctx.workload, ctx.nest, ctx.backend
    try:
        vmem = codegen.vmem_bytes(w, nest)
    except codegen.CodegenError as e:
        yield Finding(
            rule="feasibility.pallas", status="compile_error",
            detail=str(e), evidence=("plan",),
        )
        return
    if model.vmem_limit is not None and vmem > model.vmem_limit:
        yield Finding(
            rule="feasibility.pallas", status="compile_error",
            detail=f"BlockSpec tiles exceed VMEM ({vmem} B)",
            evidence=(vmem, model.vmem_limit),
        )
        return
    if model.verify:
        ws = w.scaled(model.scale)
        nest_small = _retile_to(nest, ws)
        try:
            plan = codegen._extract_plan(ws, nest_small)
            for v, _trips, span in plan.grid:
                if span % plan.tile[v] != 0:
                    yield Finding(
                        rule="feasibility.pallas", status="compile_error",
                        detail=(f"var {v!r}: floor span {span} not a multiple "
                                f"of its block width {plan.tile[v]} at "
                                f"verification scale {model.scale}"),
                        evidence=(v, span, plan.tile[v]),
                    )
                    return
        except codegen.CodegenError as e:
            yield Finding(
                rule="feasibility.pallas", status="compile_error",
                detail=f"at verification scale {model.scale}: {e}",
                evidence=("verify-plan",),
            )


@register_pass("feasibility.kernel")
def _kernel(ctx: AnalysisContext) -> Iterable[Finding]:
    """Mirror of ``PallasBackend._measure`` for kernel workloads (the repo's
    hand-written Pallas kernels): the kernel's own expressibility conditions
    (stacked tilings, reordered grids, non-tileable dims, unroll/vectorize)
    raise through ``vmem_bytes``/``kernel_params``, plus the VMEM budget."""
    w, nest, model = ctx.workload, ctx.nest, ctx.backend
    try:
        vmem = w.vmem_bytes(nest)
    except codegen.CodegenError as e:
        yield Finding(
            rule="feasibility.kernel", status="compile_error",
            detail=str(e), evidence=("blocks",),
        )
        return
    if model.vmem_limit is not None and vmem > model.vmem_limit:
        yield Finding(
            rule="feasibility.kernel", status="compile_error",
            detail=f"BlockSpec tiles exceed VMEM ({vmem} B)",
            evidence=(vmem, model.vmem_limit),
        )
        return
    if model.verify:
        ws = w.scaled(model.scale)
        nest_small = _retile_to(nest, ws)
        try:
            ws.kernel_params(nest_small)
        except codegen.CodegenError as e:
            yield Finding(
                rule="feasibility.kernel", status="compile_error",
                detail=f"at verification scale {model.scale}: {e}",
                evidence=("verify-blocks",),
            )


# ---------------------------------------------------------------------------
# The analyzer
# ---------------------------------------------------------------------------

_DEP_PASSES = ("dependence.parallel-reduction", "dependence.triangular")


def default_passes(workload, model: BackendModel) -> tuple[str, ...]:
    """Passes that soundly apply to (workload, backend).  Dependence passes
    always apply — every backend calls ``check_legal``.  Feasibility passes
    only when the backend actually enforces the mirrored condition."""
    names = list(_DEP_PASSES)
    kernel = _is_kernel_workload(workload)
    if model.kind == "wallclock" and not kernel:
        names.append("feasibility.xla")
    elif model.kind == "pallas":
        names.append("feasibility.kernel" if kernel else "feasibility.pallas")
    return tuple(names)


class StaticAnalyzer:
    """Runs the selected passes over transformed nests for one (workload,
    backend) pair.  ``analyze`` returns a :class:`Verdict`; infeasible means
    the modeled backend would deterministically reject the schedule."""

    def __init__(self, workload, backend=None, passes=None):
        self.workload = workload
        self.model = (backend if isinstance(backend, BackendModel)
                      else BackendModel.of(backend) if backend is not None
                      else BackendModel("generic"))
        names = tuple(passes) if passes is not None else default_passes(
            workload, self.model)
        unknown = [n for n in names if n not in _PASSES]
        if unknown:
            raise ValueError(f"unknown analysis pass(es): {unknown}")
        self.passes = names

    def analyze(self, nest: LoopNest, config=None) -> Verdict:
        ctx = AnalysisContext(
            workload=self.workload, nest=nest, config=config,
            backend=self.model,
        )
        findings: list[Finding] = []
        for name in self.passes:
            findings.extend(_PASSES[name](ctx))
        return Verdict(
            feasible=not findings,
            findings=tuple(findings),
            passes_run=self.passes,
        )
