"""Static schedule analysis — dependence-based red-node prediction.

The paper does *no* a-priori pruning: every illegal configuration is found by
compiling it (§IV-B), which is why syr2k's tree is dominated by red nodes
(§VI-B).  This package predicts the backends' *deterministic* red nodes
statically so the evaluation engine can reject them without dispatching a
measurement worker:

* :mod:`repro.analysis.deps` — a dependence analyzer computing distance /
  direction vectors from the ``Access`` patterns of a :class:`LoopNest`.
* :mod:`repro.analysis.passes` — the pass-manager core: named passes over the
  dependence evidence plus backend-feasibility mirrors (VMEM capacity, grid
  budget, codegen/kernel expressibility), producing a :class:`Verdict` with
  provenance (which rule fired, on which evidence).
* :mod:`repro.analysis.differential` — the soundness harness cross-checking
  static verdicts against actual backend verdicts over sampled schedules.
  Hard invariant: **zero false infeasibles** — anything a backend accepts must
  pass static analysis.
* :mod:`repro.analysis.lint` — ``python -m repro.analysis.lint spec.json``
  reports a space's statically-infeasible fraction and per-rule histogram
  before a job is submitted to the fleet; :func:`lint_spec` is the callable
  form the fleet dispatcher runs at the door (bad specs are rejected with a
  typed :class:`LintError` instead of burning a worker).

Opt-in at every layer (``EvaluationEngine(static_analysis=True)``,
``TuningSession``, ``TuningSpec``); default-off runs stay byte-identical.
"""

from .deps import Dependence, dependences, source_order
from .passes import (
    AnalysisContext,
    BackendModel,
    Finding,
    StaticAnalyzer,
    Verdict,
    available_passes,
    register_pass,
)
from .differential import DifferentialReport, run_differential, sample_configs
from .lint import LintError, lint_spec

__all__ = [
    "AnalysisContext",
    "BackendModel",
    "Dependence",
    "DifferentialReport",
    "Finding",
    "LintError",
    "StaticAnalyzer",
    "Verdict",
    "available_passes",
    "dependences",
    "lint_spec",
    "register_pass",
    "run_differential",
    "sample_configs",
    "source_order",
]
