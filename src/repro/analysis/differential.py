"""Differential soundness harness: static verdicts vs actual backend verdicts.

Samples schedules from a workload's :class:`SearchSpace` by seeded random
walks, analyzes each with the :class:`StaticAnalyzer` configured for the
backend under test, evaluates the same schedule with the *real* backend, and
tallies:

* **false infeasibles** — backend says ``ok`` but static analysis rejected.
  The hard invariant is that this set is **empty**: a false infeasible means
  the engine would silently hide a viable schedule from the search.
* **coverage** — fraction of backend red nodes the analyzer predicted.  This
  is best-effort (nondeterministic failures are out of scope by design) and
  reported per rule.

For the wallclock backend, real execution over thousands of schedules is not
affordable in CI; ``wallclock_dry_verdict`` runs the backend's exact
deterministic prefix — scaled re-derivation, ``check_legal``,
``codegen.build_xla`` *construction* (which raises every deterministic
``CodegenError`` before any tracing or execution) — so the oracle is still
the production code path, minus the timed run.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

from repro.core import codegen
from repro.core.legality import IllegalTransform, check_legal
from repro.core.loopnest import LoopNest
from repro.core.measure import Result
from repro.core.searchspace import Configuration, SearchSpace
from repro.core.transformations import TransformError

from .passes import StaticAnalyzer

__all__ = [
    "DifferentialReport",
    "run_differential",
    "sample_configs",
    "wallclock_dry_verdict",
]


def sample_configs(
    space: SearchSpace,
    n: int,
    seed: int = 0,
    max_depth: int = 4,
    restart: float = 0.3,
) -> list[Configuration]:
    """``n`` distinct derivable configurations by seeded random walks with
    restarts (depth ≥ 1 — the root is trivially feasible everywhere).  Walks
    restart at broken structures, dead ends, and the depth cap, so samples
    spread over shallow and deep schedules."""
    rng = random.Random(seed)
    out: list[Configuration] = []
    seen: set[tuple] = set()
    cur = Configuration()
    budget = max(n * 60, 2000)
    while len(out) < n and budget > 0:
        budget -= 1
        if len(cur) >= max_depth or rng.random() < restart:
            cur = Configuration()
            continue
        kids = space.children(cur, dedup=False)
        if not kids:
            cur = Configuration()
            continue
        cur = kids[rng.randrange(len(kids))]
        if not isinstance(space.try_structure(cur), LoopNest):
            cur = Configuration()
            continue
        pk = cur.path_key()
        if pk not in seen:
            seen.add(pk)
            out.append(cur)
    return out


def wallclock_dry_verdict(backend, workload, config: Configuration) -> Result:
    """The wallclock backend's deterministic prefix, via the production code:
    scaled re-derivation → legality → ``build_xla`` construction.  Returns
    ``ok`` when the prefix accepts (the real backend would proceed to run)."""
    w = workload.scaled(backend.scale)
    try:
        nest = config.apply(w.nest())
    except TransformError as e:
        return Result("compile_error", note=str(e))
    try:
        check_legal(nest)
    except IllegalTransform as e:
        return Result("illegal", note=str(e))
    try:
        codegen.build_xla(w, nest)
    except codegen.CodegenError as e:
        return Result("compile_error", note=str(e))
    return Result("ok", time_s=0.0)


@dataclass
class DifferentialReport:
    """Tally of one (workload, backend) differential run."""

    workload: str
    backend: str
    samples: int = 0
    backend_red: int = 0
    predicted_red: int = 0
    agreed_red: int = 0                      # red on both sides
    false_infeasible: list[dict] = field(default_factory=list)
    by_rule: dict[str, int] = field(default_factory=dict)
    uncovered: dict[str, int] = field(default_factory=dict)   # note-prefix → count

    @property
    def sound(self) -> bool:
        return not self.false_infeasible

    @property
    def coverage(self) -> float:
        return self.agreed_red / self.backend_red if self.backend_red else 1.0

    def to_dict(self) -> dict:
        return {
            "workload": self.workload,
            "backend": self.backend,
            "samples": self.samples,
            "backend_red": self.backend_red,
            "predicted_red": self.predicted_red,
            "agreed_red": self.agreed_red,
            "coverage": round(self.coverage, 4),
            "false_infeasible": self.false_infeasible,
            "by_rule": dict(sorted(self.by_rule.items())),
            "uncovered": dict(sorted(self.uncovered.items())),
            "sound": self.sound,
        }


def _note_prefix(note: str) -> str:
    return note.split(":", 1)[0][:60] if note else "(none)"


def run_differential(
    workload,
    backend,
    *,
    space: SearchSpace | None = None,
    samples: int = 2000,
    seed: int = 0,
    max_depth: int = 4,
    dry: bool = False,
    label: str | None = None,
) -> DifferentialReport:
    """Cross-check static verdicts against the backend over sampled schedules.

    ``dry=True`` (wallclock only) uses :func:`wallclock_dry_verdict` instead
    of a timed run.  Every sampled configuration is derivable at full scale —
    underivable ones never reach a backend through the engine anyway."""
    space = space or SearchSpace(root=workload.nest())
    configs = sample_configs(space, samples, seed=seed, max_depth=max_depth)
    analyzer = StaticAnalyzer(workload, backend=backend)
    rep = DifferentialReport(
        workload=getattr(workload, "name", "?"),
        backend=label or getattr(backend, "name", "?"),
        samples=len(configs),
    )
    for config in configs:
        nest = space.try_structure(config)
        verdict = analyzer.analyze(nest, config=config)
        if dry:
            res = wallclock_dry_verdict(backend, workload, config)
        else:
            res = backend.evaluate(workload, config, nest=nest)
        if res.ok and not verdict.feasible:
            rep.false_infeasible.append({
                "path": [repr(t) for t in config.transformations],
                "rule": verdict.rule,
                "detail": verdict.detail,
            })
        if not verdict.feasible:
            rep.predicted_red += 1
            rep.by_rule[verdict.rule] = rep.by_rule.get(verdict.rule, 0) + 1
        if not res.ok:
            rep.backend_red += 1
            if verdict.feasible:
                p = _note_prefix(res.note)
                rep.uncovered[p] = rep.uncovered.get(p, 0) + 1
            else:
                rep.agreed_red += 1
    return rep
