"""Dependence analysis over :class:`LoopNest` access patterns.

This is the machine-checked counterpart of the prose model in
``repro.core.legality``: instead of hand-coded rules over loop attributes, we
derive explicit *dependences* from the nest's ``Access`` patterns and bound
metadata, each carrying a distance vector over the source iteration space and
a direction vector over the transformed loop order.  The legality passes in
:mod:`repro.analysis.passes` then reject schedules from this evidence alone,
and the differential harness checks the result against ``check_legal`` (the
oracle) and the real backends.

Two dependence kinds cover the model:

* ``reduction`` — a ``reduce`` access ``C[i][j] += ...`` carries a dependence
  on every source loop that does *not* index ``C``: iterations differing only
  in that loop hit the same element, giving the elementary distance vector
  ``(0, …, 1, …, 0)`` (1 in the carried var's position).  Parallelizing any
  transformed loop derived from that var reorders a chain of read-modify-write
  accumulations (Polly refuses this too — paper §V: associativity is not
  considered).
* ``bound`` — a triangular pair ``(provider, dependent)`` (``for j <= i``)
  makes the dependent loop's bound a *value* dependence on the provider's
  induction variable.  It has no fixed distance; what matters is the
  structural relation of the two vars' transformed loops (ordering, tiling
  balance), which the triangular pass inspects.

Direction vectors use the classic ``"<" / "=" / "*"`` alphabet per transformed
loop, outermost→innermost: ``"="`` for loops not derived from the carried var;
``"<"`` at the outermost loop derived from it (the dependence is carried
forward there); ``"*"`` for the inner derived loops — after strip-mining, the
cross-tile instances of a distance-1 dependence take both signs at the point
loop (distance ``(1, 1-T)`` across a tile boundary of size ``T``), so the
component is unknown.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.loopnest import LoopNest

__all__ = ["Dependence", "dependences", "source_order"]


@dataclass(frozen=True)
class Dependence:
    """One loop-carried dependence, with the evidence it was derived from.

    ``var`` is the *source-level* loop carrying it.  For ``reduction`` kind,
    ``array`` is the accumulated array and ``distance``/``direction`` are the
    vectors described in the module docstring.  For ``bound`` kind, ``var`` is
    the dependent var, ``provider`` the bound-providing var, and the vectors
    are empty (the dependence is on the induction *value*, not an iteration
    offset).
    """

    kind: str                           # "reduction" | "bound"
    var: str                            # source loop carrying the dependence
    array: str = ""                     # reduction: the accumulated array
    provider: str = ""                  # bound: the bound-providing var
    distance: tuple[int, ...] = ()      # over source_order(nest)
    direction: tuple[str, ...] = ()     # over nest.loops (outermost→innermost)

    def describe(self) -> str:
        if self.kind == "reduction":
            return (f"reduction on {self.array!r} carried by {self.var!r} "
                    f"(distance {self.distance}, direction {self.direction})")
        return f"bound of {self.var!r} provided by {self.provider!r}"


def source_order(nest: LoopNest) -> tuple[str, ...]:
    """Canonical ordering of source-level loop vars: order of first appearance
    in the transformed nest, then any extent-only vars (fully-unrolled or
    degenerate dims) in extents order.  Distance vectors index this order."""
    order: dict[str, None] = {}
    for l in nest.loops:
        order.setdefault(l.origin)
    for v in nest.extents:
        order.setdefault(v)
    return tuple(order)


def dependences(nest: LoopNest) -> tuple[Dependence, ...]:
    """All loop-carried dependences of the transformed nest."""
    srcs = source_order(nest)
    pos = {v: i for i, v in enumerate(srcs)}
    out: list[Dependence] = []

    # Reduction dependences: one elementary distance-1 dependence per
    # (reduce access, source var not indexing it).
    for a in nest.accesses:
        if a.kind != "reduce":
            continue
        for v in srcs:
            if v in a.vars:
                continue
            dist = tuple(1 if i == pos[v] else 0 for i in range(len(srcs)))
            direction: list[str] = []
            first = True
            for l in nest.loops:
                if l.origin != v:
                    direction.append("=")
                elif first:
                    direction.append("<")
                    first = False
                else:
                    direction.append("*")
            out.append(Dependence(
                kind="reduction", var=v, array=a.array,
                distance=dist, direction=tuple(direction),
            ))

    # Bound dependences: the triangular pairs, kept only when both vars still
    # have loops in the transformed nest (a fully-degenerate dim carries no
    # structural constraint — mirrors check_legal's `if not prov or not dep`).
    present = {l.origin for l in nest.loops}
    for provider, dependent in nest.triangular:
        if provider in present and dependent in present:
            out.append(Dependence(kind="bound", var=dependent, provider=provider))

    return tuple(out)
