"""Sharded, step-atomic checkpointing with reshard-on-restore.

Layout (one directory per step)::

    <dir>/step_000123/
        index.json            # tree structure, shapes, dtypes, shard map
        <leaf-id>.npy         # one file per host-local shard (addressable data)
    <dir>/step_000123.COMMIT  # written last → a step without COMMIT is garbage

Design points for the 1000-node posture:

* every process writes only its *addressable* shards; the index records which
  process wrote what, so restore works with any later topology (shards are
  re-assembled to global arrays and re-sharded onto the new mesh — elastic
  restarts across different pod counts),
* the COMMIT marker makes saves atomic w.r.t. crashes mid-write,
* saves can run on a background thread (``async_save``) double-buffering the
  host copy, so the step loop is not blocked by disk,
* restore is bit-exact (tested in tests/test_checkpoint.py): a run killed at
  step k and restarted produces the same losses as an uninterrupted run.

On this single-process container every shard is addressable, which exercises
the same code paths with process_count == 1.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

Pytree = Any


def _leaf_id(i: int) -> str:
    return f"leaf_{i:05d}"


def save(directory: str | pathlib.Path, step: int, tree: Pytree) -> pathlib.Path:
    """Synchronous sharded save.  Returns the step directory."""
    base = pathlib.Path(directory)
    stepdir = base / f"step_{step:09d}"
    tmp = base / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    index = {
        "step": step,
        "treedef": str(treedef),     # structure descriptor (restore validates
                                     # against the caller-supplied `like` tree)
        "leaves": [],
        "process": jax.process_index(),
        "time": time.time(),
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if arr.dtype.kind not in "biufc":       # ml_dtypes (bf16, fp8, ...)
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        np.save(tmp / f"{_leaf_id(i)}.npy", arr)
        index["leaves"].append({
            "id": _leaf_id(i),
            "shape": list(arr.shape),
            "dtype": dtype_name,
        })
    (tmp / "index.json").write_text(json.dumps(index))
    if stepdir.exists():
        shutil.rmtree(stepdir)
    tmp.rename(stepdir)
    (base / f"step_{step:09d}.COMMIT").write_text(str(time.time()))
    return stepdir


class AsyncCheckpointer:
    """Background-thread checkpointer: snapshot to host, write off-thread."""

    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree: Pytree) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save(self.directory, step, host_tree)
                self._gc()
            except Exception as e:      # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(committed_steps(self.directory))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:09d}", ignore_errors=True)
            (self.directory / f"step_{s:09d}.COMMIT").unlink(missing_ok=True)


def committed_steps(directory: str | pathlib.Path) -> list[int]:
    base = pathlib.Path(directory)
    if not base.exists():
        return []
    return sorted(
        int(p.name[len("step_"):-len(".COMMIT")])
        for p in base.glob("step_*.COMMIT"))


def latest_step(directory: str | pathlib.Path) -> int | None:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str | pathlib.Path, step: int, like: Pytree,
            shardings: Pytree | None = None) -> Pytree:
    """Restore onto the current mesh.  ``like`` supplies the tree structure;
    ``shardings`` (optional tree of NamedShardings) re-shards each leaf —
    restoring onto a *different* mesh than the one that saved is supported
    (elastic restart)."""
    stepdir = pathlib.Path(directory) / f"step_{step:09d}"
    index = json.loads((stepdir / "index.json").read_text())
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(leaves_like) == len(index["leaves"]), (
        f"checkpoint has {len(index['leaves'])} leaves, tree expects "
        f"{len(leaves_like)}")
    shard_leaves = (jax.tree_util.tree_flatten(
        shardings, is_leaf=lambda x: x is None or hasattr(x, "spec"))[0]
        if shardings is not None else [None] * len(leaves_like))
    out = []
    for i, (meta, leaf_like, shd) in enumerate(
            zip(index["leaves"], leaves_like, shard_leaves)):
        arr = np.load(stepdir / f"{meta['id']}.npy")
        if str(arr.dtype) != meta["dtype"]:     # ml_dtypes stored as uint view
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"])))
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
