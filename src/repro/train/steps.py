"""Step functions: training (loss + grad + AdamW update, optional microbatch
gradient accumulation) and serving (prefill / decode) — the functions the
launcher jits, shards, and the dry-run lowers."""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import sharding as sh
from repro.models.model import Model, build_model
from repro.optim import OptimizerConfig, apply_updates, init_opt_state

Pytree = Any


@dataclass(frozen=True)
class TrainState:
    params: Pytree
    opt: Pytree
    step: jax.Array


def make_train_step(model: Model, opt_cfg: OptimizerConfig,
                    microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` accumulates gradients over batch slices with a scan —
    the standard memory/overlap lever the §Perf tuner can move.
    """

    def loss_fn(params, batch):
        loss, aux = model.loss(params, batch)
        return loss, aux

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def slice_mb(i, t):
                mb = t.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(t, i * mb, mb, axis=0)

            def acc_body(carry, i):
                gsum, lsum, asum = carry
                mb = jax.tree.map(functools.partial(slice_mb, i), batch)
                (l, a), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l, asum + a), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum, asum), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss, aux = lsum / microbatches, asum / microbatches

        params, opt_state, metrics = apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, aux_loss=aux)
        return params, opt_state, metrics

    return train_step


def make_serve_steps(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    def decode_step(params, tokens, caches, pos):
        logits, caches = model.decode_step(params, tokens, caches, pos)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, logits, caches

    return prefill_step, decode_step


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs) per (arch × shape cell) — dry-run stand-ins
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train: the token batch (B, S+1) (+ stub modality inputs);
    prefill: prompt batch (B, S);
    decode: one new token against a KV/state cache of S (built separately).
    """
    B, S = cell.global_batch, cell.seq_len
    sp: dict[str, jax.ShapeDtypeStruct] = {}
    if cell.kind == "train":
        ntok = S + 1
        if cfg.family == "vlm":
            # patches replace leading positions: text tokens = S - patches
            sp["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype))
            ntok = S - cfg.num_patches + 1
        if cfg.family == "audio":
            sp["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        sp["tokens"] = jax.ShapeDtypeStruct((B, ntok), jnp.int32)
        return sp
    if cell.kind == "prefill":
        ntok = S
        if cfg.family == "vlm":
            sp["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype))
            ntok = S - cfg.num_patches
        if cfg.family == "audio":
            sp["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        sp["tokens"] = jax.ShapeDtypeStruct((B, ntok), jnp.int32)
        return sp
    # decode: one token per sequence
    sp["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    sp["pos"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    return sp


def batch_axes(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Logical sharding axes for the input batch dict."""
    if cell.kind == "decode":
        return {"tokens": ("batch", None), "pos": ("batch",)}
    a: dict[str, tuple] = {"tokens": ("batch", None)}
    if cfg.family == "vlm":
        a["patches"] = ("batch", None, None)
    if cfg.family == "audio":
        a["frames"] = ("batch", None, None)
    return a
