"""The training loop: data prefetch → jitted step → watchdog → async
checkpoints, with restart-from-commit (fault tolerance) built in.

Small enough to read, complete enough to run the e2e example
(examples/train_lm.py trains a ~100M-param config for a few hundred steps on
this container) and structured the way a pod-scale launcher drives it.
"""

from __future__ import annotations

import functools
import pathlib
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, Prefetcher, host_batch
from repro.models import sharding as sh
from repro.models.model import build_model
from repro.optim import OptimizerConfig, apply_updates, init_opt_state
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (FailureInjector, StragglerWatchdog,
                                         SimulatedFailure)
from repro.train.steps import make_train_step


@dataclass
class LoopConfig:
    total_steps: int = 200
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    microbatches: int = 1
    keep_ckpts: int = 3


@dataclass
class LoopResult:
    last_step: int
    losses: list = field(default_factory=list)
    straggler_flags: list = field(default_factory=list)
    restored_from: int | None = None


def train(cfg: ModelConfig, opt_cfg: OptimizerConfig, loop: LoopConfig,
          data_cfg: DataConfig | None = None,
          injector: FailureInjector | None = None,
          mesh=None, rules=None) -> LoopResult:
    """Run (or resume) training.  Restores from the latest committed
    checkpoint in ``loop.ckpt_dir`` if one exists."""
    model = build_model(cfg)
    data_cfg = data_cfg or DataConfig(
        vocab_size=cfg.vocab_size, seq_len=256, global_batch=8,
        seed=loop.seed)
    step_fn = make_train_step(model, opt_cfg, microbatches=loop.microbatches)

    with sh.scope(mesh, rules) if mesh is not None else _nullcontext():
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

        params = model.init(jax.random.key(loop.seed))
        opt_state = init_opt_state(opt_cfg, params)
        start_step = 0
        restored = None
        latest = ckpt.latest_step(loop.ckpt_dir)
        if latest is not None:
            state = ckpt.restore(loop.ckpt_dir, latest, (params, opt_state))
            params, opt_state = state
            start_step = latest
            restored = latest

        saver = ckpt.AsyncCheckpointer(loop.ckpt_dir, keep=loop.keep_ckpts)
        watchdog = StragglerWatchdog()
        result = LoopResult(last_step=start_step, restored_from=restored)

        prefetch = Prefetcher(data_cfg, start_step=start_step)
        try:
            for step in range(start_step, loop.total_steps):
                got_step, batch_np = prefetch.next()
                assert got_step == step, (got_step, step)
                batch = {"tokens": jax.numpy.asarray(batch_np)}
                _extend_batch(batch, cfg, data_cfg, step)
                t0 = time.perf_counter()
                if injector is not None:
                    injector.maybe_fail(step)
                params, opt_state, metrics = jitted(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                if watchdog.observe(step, dt):
                    result.straggler_flags.append(step)
                if step % loop.log_every == 0 or step == loop.total_steps - 1:
                    result.losses.append((step, loss))
                next_step = step + 1
                if next_step % loop.ckpt_every == 0:
                    saver.save(next_step, (params, opt_state))
                result.last_step = next_step
            saver.save(loop.total_steps, (params, opt_state))
            saver.wait()
        finally:
            prefetch.close()
        return result


def _extend_batch(batch, cfg, data_cfg, step):
    """Stub modality inputs for vlm/audio families (deterministic)."""
    import jax.numpy as jnp

    B = batch["tokens"].shape[0]
    if cfg.family == "vlm":
        rng = np.random.default_rng([data_cfg.seed, step, 7])
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patches, cfg.d_model), np.float32))
    if cfg.family == "audio":
        rng = np.random.default_rng([data_cfg.seed, step, 9])
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model), np.float32))


class _nullcontext:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
