"""Fault-tolerance & elasticity machinery for the training loop.

Components (all exercised by tests on this single-process container; on a real
cluster the same hooks attach to the coordination service):

* :class:`StragglerWatchdog` — per-step wall-time EWMA + deviation tracking;
  steps slower than ``mean + k·std`` (and an absolute floor) are flagged.  The
  loop's policy on a flagged step is configurable: ``"log"`` (default),
  ``"checkpoint"`` (defensive save — a slow step often precedes an ICI/host
  failure), or a user callback (e.g. re-shard away from the slow host).
* :class:`FailureInjector` — deterministic chaos hook for tests/examples:
  raises :class:`SimulatedFailure` at configured steps so the restart path is
  actually executed, not just theorised.
* :func:`run_with_restarts` — supervisor that runs a training function,
  catches (simulated) failures, restores from the latest committed checkpoint
  and resumes — optionally onto a *different* mesh (elastic restart), since
  checkpoints reshard on restore (train/checkpoint.py).

Design for 1000+ nodes (documented posture): the watchdog statistics and the
restart barrier are per-host and coordinated through jax's distributed
runtime; checkpoint COMMIT markers come from process 0 after a barrier, and
data-pipeline determinism (data/pipeline.py) guarantees every host regenerates
exactly its shard of the step stream after re-sharding.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests/examples)."""


@dataclass
class StragglerWatchdog:
    k_std: float = 4.0
    min_steps: int = 8
    abs_floor_s: float = 0.05
    policy: str = "log"                 # log | checkpoint | callback
    callback: Callable[[int, float], None] | None = None
    _n: int = 0
    _mean: float = 0.0
    _m2: float = 0.0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if the step is a straggler."""
        self._n += 1
        delta = dt - self._mean
        self._mean += delta / self._n
        self._m2 += delta * (dt - self._mean)
        if self._n < self.min_steps:
            return False
        std = math.sqrt(self._m2 / max(self._n - 1, 1))
        slow = dt > max(self._mean + self.k_std * std,
                        self._mean + self.abs_floor_s)
        if slow:
            self.flagged.append((step, dt, self._mean))
            if self.policy == "callback" and self.callback:
                self.callback(step, dt)
        return slow


@dataclass
class FailureInjector:
    fail_at_steps: tuple[int, ...] = ()
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


def run_with_restarts(train_fn: Callable[[int], int],
                      max_restarts: int = 3,
                      on_restart: Callable[[int, Exception], None] | None = None
                      ) -> tuple[int, int]:
    """Supervise ``train_fn(start_step) -> last_step`` across failures.

    ``train_fn`` must restore its own state from the latest committed
    checkpoint when invoked with a start step.  Returns (last_step, restarts).
    """
    restarts = 0
    start = 0
    while True:
        try:
            return train_fn(start), restarts
        except SimulatedFailure as e:     # noqa: PERF203
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart:
                on_restart(restarts, e)
            # train_fn re-reads the latest commit; start is advisory
            start = -1
