"""Tunable covariance Pallas kernel — PolyBench covariance's rank-k update
(§V-C): cov[i,j] = Σ_k data[k,i]·data[k,j] for j ≥ i (upper triangular).

Note the transposed access pattern data[k,i]: the reduction runs over the
*rows* of data, so the natural MXU mapping is dataᵀ·data with the k-dim as the
contraction — the kernel reads (block_k, block_i) column panels, which is why
the tuner prefers larger block_k here than for gemm (EXPERIMENTS.md)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _cov_kernel(di_ref, dj_ref, o_ref, acc_ref, *, block_i, block_j):
    @pl.when(pl.program_id(2) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # cov_tile[i,j] += data[:,i]^T · data[:,j]
    acc_ref[...] += jnp.dot(
        di_ref[...].T, dj_ref[...], preferred_element_type=jnp.float32
    )

    gi = pl.program_id(0) * block_i
    gj = pl.program_id(1) * block_j

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _():
        rows = gi + jax.lax.broadcasted_iota(jnp.int32, (block_i, block_j), 0)
        cols = gj + jax.lax.broadcasted_iota(jnp.int32, (block_i, block_j), 1)
        o_ref[...] = jnp.where(cols >= rows, acc_ref[...], 0.0).astype(o_ref.dtype)


def covariance(
    data: jnp.ndarray,
    *,
    block_i: int = 256,
    block_j: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    k, m = data.shape
    bi, bj, bk = min(block_i, m), min(block_j, m), min(block_k, k)
    assert m % bi == 0 and m % bj == 0 and k % bk == 0
    kern = functools.partial(_cov_kernel, block_i=bi, block_j=bj)
    return pl.pallas_call(
        kern,
        grid=(m // bi, m // bj, k // bk),
        in_specs=[
            pl.BlockSpec((bk, bi), lambda i, j, l: (l, i)),
            pl.BlockSpec((bk, bj), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(data, data)
