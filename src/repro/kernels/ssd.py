"""Mamba-2 SSD (state-space duality) chunked-scan Pallas kernel.

The SSD algorithm (Dao & Gu, arXiv:2405.21060) is itself a *tiling* of a linear
recurrence: the chunk length is a tile size trading intra-chunk matmul work
(MXU-friendly, quadratic in chunk) against inter-chunk sequential state passing
— i.e. the paper's search space applies to the chunk length directly, which is
why mamba2 is one of the §Perf hillclimb candidates.

Kernel layout: grid = (batch·head, n_chunks) with the chunk dim sequential
("arbitrary" semantics — it carries the (P, N) state in VMEM scratch).  Each
step does three MXU contractions (CBᵀ scores, score·x, state update) on
(chunk × N/P) tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref,
                *, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)        # (chunk, P)
    dt = dt_ref[0].astype(jnp.float32)      # (chunk, 1)
    a = a_ref[0, 0]                         # scalar decay rate (negative)
    b = b_ref[0].astype(jnp.float32)        # (chunk, N)
    c = c_ref[0].astype(jnp.float32)        # (chunk, N)

    la = dt[:, 0] * a                       # (chunk,) log-decay
    cum = jnp.cumsum(la)                    # inclusive
    # intra-chunk lower-triangular decay kernel (masked before exp — the
    # upper entries have positive exponents that overflow)
    seg = cum[:, None] - cum[None, :]
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    decay = jnp.exp(jnp.where(tri, seg, -1e30))
    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32) * decay
    y = jnp.dot(scores * dt[:, 0][None, :], x,
                preferred_element_type=jnp.float32)            # (chunk, P)
    # inter-chunk: incoming state contribution
    h = h_ref[...]                                             # (N, P)
    y += jnp.exp(cum)[:, None] * jnp.dot(c, h,
                                         preferred_element_type=jnp.float32)
    # state update: h' = exp(total)·h + Σ_s exp(total-cum_s)·dt_s·b_s⊗x_s
    total = cum[-1]
    w = jnp.exp(total - cum) * dt[:, 0]                        # (chunk,)
    h_ref[...] = jnp.exp(total) * h + jnp.dot(
        (b * w[:, None]).T, x, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(
    x: jnp.ndarray,          # (BH, L, P)   batch·heads flattened
    dt: jnp.ndarray,         # (BH, L, 1)
    a: jnp.ndarray,          # (BH, 1, 1)   per-head decay rate
    b: jnp.ndarray,          # (BH, L, N)   already head-grouped
    c: jnp.ndarray,          # (BH, L, N)
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    BH, L, P = x.shape
    N = b.shape[-1]
    ch = min(chunk, L)
    # Non-divisible chunk: pad the sequence axis with zeros.  The scan is
    # causal left-to-right, so zero-padded trailing steps (x=b=c=0, dt=0)
    # never influence y[:, :L]; the padded rows are sliced off the output.
    l_p = -(-L // ch) * ch
    if l_p != L:
        pad = ((0, 0), (0, l_p - L), (0, 0))
        x, dt, b, c = (jnp.pad(t, pad) for t in (x, dt, b, c))
    kern = functools.partial(_ssd_kernel, chunk=ch)
    out = pl.pallas_call(
        kern,
        grid=(BH, l_p // ch),
        in_specs=[
            pl.BlockSpec((1, ch, P), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, ch, 1), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, 1, 1), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, ch, N), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, ch, N), lambda h, i: (h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, ch, P), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, l_p, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return out[:, :L]
