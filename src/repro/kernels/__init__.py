"""Pallas TPU kernels for the performance-critical compute layers.

Each kernel has explicit ``BlockSpec`` VMEM tiling whose block sizes are the
paper's tile-size parameters (tuned by ``repro.core``), a jit'd wrapper in
:mod:`repro.kernels.ops`, and a pure-jnp oracle in :mod:`repro.kernels.ref`.
All kernels are validated in interpret mode on CPU; on a TPU backend the same
calls lower to Mosaic.
"""

from .ops import covariance, flash_attention, matmul, ssd_scan, syr2k

__all__ = ["covariance", "flash_attention", "matmul", "ssd_scan", "syr2k"]
