"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth the kernels are asserted against
(interpret=True on CPU, real Mosaic on TPU).  They are deliberately written as
straight-line jnp — no blocking, no tricks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C[i,j] = Σ_k A[i,k]·B[k,j] (PolyBench gemm core, f32 accumulation)."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def syr2k_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular C[i,j] = Σ_k A[j,k]B[i,k] + B[j,k]A[i,k] (j ≤ i)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    full = b @ a.T + a @ b.T
    return jnp.tril(full)


def covariance_ref(data: jnp.ndarray) -> jnp.ndarray:
    """Upper-triangular cov[i,j] = Σ_k data[k,i]·data[k,j] (j ≥ i), with the
    mean already subtracted (PolyBench subtracts the column mean first; the
    tunable nest is the rank-k update)."""
    d = data.astype(jnp.float32)
    return jnp.triu(d.T @ d)


def attention_ref(
    q: jnp.ndarray,          # (B, Hq, Sq, D)
    k: jnp.ndarray,          # (B, Hkv, Skv, D)
    v: jnp.ndarray,          # (B, Hkv, Skv, D)
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    """Grouped-query softmax attention, f32 softmax."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if causal:
        # last Sq queries of a length-Skv context
        Skv = k.shape[2]
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
        kpos = jnp.arange(Skv)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def decode_attention_ref(
    q: jnp.ndarray,          # (B, Hq, D) — one query token
    k: jnp.ndarray,          # (B, Hkv, S, D)
    v: jnp.ndarray,          # (B, Hkv, S, D)
    length: jnp.ndarray | None = None,   # (B,) valid KV lengths
) -> jnp.ndarray:
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32) * scale, kf)
    if length is not None:
        mask = jnp.arange(S)[None, None, :] < length[:, None, None]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, vf).astype(q.dtype)


def ssd_ref_recurrent(
    x: jnp.ndarray,          # (L, H, P)
    dt: jnp.ndarray,         # (L, H)      — softplus already applied
    a: jnp.ndarray,          # (H,)        — negative decay rates
    b: jnp.ndarray,          # (L, G, N)
    c: jnp.ndarray,          # (L, G, N)
    h0: jnp.ndarray | None = None,   # (H, P, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba-2 SSD as the literal recurrence (the slowest, most obviously
    correct form).  h_t = exp(dt_t·a)·h_{t-1} + dt_t·(x_t ⊗ b_t);
    y_t = h_t · c_t.  Heads are grouped over B/C (G groups)."""
    L, H, P = x.shape
    G, N = b.shape[1], b.shape[2]
    hpg = H // G
    if h0 is None:
        h0 = jnp.zeros((H, P, N), jnp.float32)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs
        decay = jnp.exp(dtt * a)[:, None, None]                 # (H,1,1)
        bg = jnp.repeat(bt, hpg, axis=0)                        # (H,N)
        cg = jnp.repeat(ct, hpg, axis=0)
        h = decay * h + (dtt[:, None] * xt)[..., None] * bg[:, None, :]
        y = jnp.einsum("hpn,hn->hp", h, cg)
        return h, y

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                         (x.astype(jnp.float32), dt.astype(jnp.float32),
                          b.astype(jnp.float32), c.astype(jnp.float32)))
    return ys.astype(x.dtype), h


def ssd_ref_chunked(
    x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
    b: jnp.ndarray, c: jnp.ndarray, chunk: int = 64,
    h0: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked (state-space dual) form — same math, O(L·chunk) attention-like
    intra-chunk term plus inter-chunk state passing.  This is the blocked
    algorithm the Pallas kernel implements; ``chunk`` is a *tile size* in the
    paper's search space."""
    L, H, P = x.shape
    G, N = b.shape[1], b.shape[2]
    hpg = H // G
    assert L % chunk == 0
    nchunks = L // chunk
    xf = x.astype(jnp.float32).reshape(nchunks, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(nchunks, chunk, H)
    bf = jnp.repeat(b.astype(jnp.float32), hpg, axis=1).reshape(nchunks, chunk, H, N)
    cf = jnp.repeat(c.astype(jnp.float32), hpg, axis=1).reshape(nchunks, chunk, H, N)
    if h0 is None:
        h0 = jnp.zeros((H, P, N), jnp.float32)

    def chunk_step(h, inputs):
        xc, dtc, bc, cc = inputs          # (chunk,H,P),(chunk,H),(chunk,H,N)×2
        la = dtc * a[None, :]             # log-decay per step (chunk,H)
        cum = jnp.cumsum(la, axis=0)      # (chunk,H) inclusive
        # intra-chunk: y_t += Σ_{s<=t} exp(cum_t - cum_s) dt_s (c_t·b_s) x_s
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        seg = cum[:, None, :] - cum[None, :, :]                 # (t,s,H)
        decay = jnp.exp(jnp.where(mask[:, :, None], seg, -1e30))
        scores = jnp.einsum("thn,shn->tsh", cc, bc) * decay
        y = jnp.einsum("tsh,sh,shp->thp", scores, dtc, xc)
        # inter-chunk: contribution of incoming state
        y += jnp.einsum("thn,hpn,th->thp", cc, h, jnp.exp(cum))
        # state update: h' = exp(total)·h + Σ_s exp(total-cum_s) dt_s b_s⊗x_s
        total = cum[-1]                   # (H,)
        w = jnp.exp(total[None, :] - cum) * dtc                 # (chunk,H)
        h = jnp.exp(total)[:, None, None] * h + jnp.einsum(
            "sh,shn,shp->hpn", w, bc, xc)
        return h, y

    h, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32),
                         (xf, dtf, bf, cf))
    return ys.reshape(L, H, P).astype(x.dtype), h
