"""Flash attention (forward) Pallas kernel — the prefill hot-spot.

IO-aware blocked attention (FlashAttention-style) adapted to the TPU memory
hierarchy: KV blocks stream HBM→VMEM, the running (m, l, acc) state lives in
VMEM scratch, and the (block_q × block_kv) score tile is sized for the MXU.
``block_q``/``block_kv`` are tile sizes in the paper's search space; the grid
order (batch·head, q, kv) with kv minor is the scratch-friendly schedule.

GQA is handled by folding the group into the q-head index map so KV blocks are
fetched once per group.  Causal masking skips fully-masked KV blocks via the
grid (cheap revisit in interpret mode; Mosaic elides the compute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale, causal, block_q, block_kv, q_offset, kv_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale         # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bkv, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (bq, bkv)

    # ``kv_len`` is the true (unpadded) KV length; when the KV axis was
    # padded to a block multiple the tail columns must never win the softmax
    kv_padded = kv_len % block_kv != 0
    if causal or kv_padded:
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0) + q_offset
        kpos = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        valid = kpos < kv_len if kv_padded else True
        if causal:
            valid = (kpos <= qpos) & valid
        s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,          # (B, Hq, Sq, D)
    k: jnp.ndarray,          # (B, Hkv, Skv, D)
    v: jnp.ndarray,          # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    block_q: int = 512,
    block_kv: int = 512,
    scale: float | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    # Non-divisible block sizes: pad both sequence axes up to a block
    # multiple.  Padded query rows are sliced off the output; padded key
    # columns are masked to NEG_INF inside the kernel (``kv_len``).
    sq_p = -(-Sq // bq) * bq
    skv_p = -(-Skv // bkv) * bkv

    qr = q.reshape(B * Hq, Sq, D)
    kr = k.reshape(B * Hkv, Skv, D)
    vr = v.reshape(B * Hkv, Skv, D)
    if sq_p != Sq:
        qr = jnp.pad(qr, ((0, 0), (0, sq_p - Sq), (0, 0)))
    if skv_p != Skv:
        kr = jnp.pad(kr, ((0, 0), (0, skv_p - Skv), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, skv_p - Skv), (0, 0)))

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=bq, block_kv=bkv, q_offset=Skv - Sq, kv_len=Skv,
    )
    out = pl.pallas_call(
        kern,
        grid=(B * Hq, sq_p // bq, skv_p // bkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, i, j, _g=group: (h // _g, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, i, j, _g=group: (h // _g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out[:, :Sq].reshape(B, Hq, Sq, D)
