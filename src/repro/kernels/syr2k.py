"""Tunable syr2k Pallas kernel — PolyBench's symmetric rank-2k update (§V-B).

C[i,j] = Σ_k A[j,k]·B[i,k] + B[j,k]·A[i,k] for j ≤ i.  The triangular output is
handled the way Polly handles non-rectangular nests: full-rectangle tiles with
the strictly-upper part masked in the final write — block (i,j) tiles entirely
above the diagonal are dead (their mask is all-zero); a production grid would
skip them, here the mask keeps the index maps affine, and the cost model's
triangular scale (0.5) accounts for the saved work.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _syr2k_kernel(a_i_ref, b_i_ref, a_j_ref, b_j_ref, o_ref, acc_ref, *, block_i, block_j):
    @pl.when(pl.program_id(2) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # C_tile[i,j] += B_i[i,k]·A_j[j,k]^T + A_i[i,k]·B_j[j,k]^T
    acc_ref[...] += jnp.dot(
        b_i_ref[...], a_j_ref[...].T, preferred_element_type=jnp.float32
    ) + jnp.dot(
        a_i_ref[...], b_j_ref[...].T, preferred_element_type=jnp.float32
    )

    gi = pl.program_id(0) * block_i
    gj = pl.program_id(1) * block_j

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _():
        rows = gi + jax.lax.broadcasted_iota(jnp.int32, (block_i, block_j), 0)
        cols = gj + jax.lax.broadcasted_iota(jnp.int32, (block_i, block_j), 1)
        o_ref[...] = jnp.where(cols <= rows, acc_ref[...], 0.0).astype(o_ref.dtype)


def syr2k(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_i: int = 256,
    block_j: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    n, k = a.shape
    assert b.shape == (n, k)
    bi, bj, bk = min(block_i, n), min(block_j, n), min(block_k, k)
    assert n % bi == 0 and n % bj == 0 and k % bk == 0
    import functools

    kern = functools.partial(_syr2k_kernel, block_i=bi, block_j=bj)
    return pl.pallas_call(
        kern,
        grid=(n // bi, n // bj, k // bk),
        in_specs=[
            pl.BlockSpec((bi, bk), lambda i, j, l: (i, l)),   # A[i,:]
            pl.BlockSpec((bi, bk), lambda i, j, l: (i, l)),   # B[i,:]
            pl.BlockSpec((bj, bk), lambda i, j, l: (j, l)),   # A[j,:]
            pl.BlockSpec((bj, bk), lambda i, j, l: (j, l)),   # B[j,:]
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(a, b, a, b)
