"""Tunable blocked matmul — the paper's gemm as a Pallas TPU kernel.

The (block_m, block_n, block_k) parameters are exactly the paper's tile sizes:
the autotuner searches them through the same tree search space
(``repro.core.workloads.matmul_workload``).  Defaults below are the TPU-v5e
cost-model optimum found by the tuner (EXPERIMENTS.md §Paper-validation).

Grid order (m, n, k) with k minor: the f32 accumulator lives in VMEM scratch
across the k-phase and the output block is written once — the "scratch_ok"
schedule of repro.core.codegen.  An (n, m, k) interchange is the same kernel
with swapped index maps; hoisting k outward is expressible but pays an output
round-trip per step, which the cost model charges accordingly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype: jnp.dtype | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """``x @ y`` with explicit VMEM tiling.  Shapes must divide the blocks
    (the ``ops`` wrapper pads); accumulation is f32."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, y.shape, (bm, bn, bk))
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
