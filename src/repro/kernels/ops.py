"""Jit'd public wrappers around the Pallas kernels.

Handles padding to block multiples, backend selection (interpret mode anywhere
without a TPU), and carries the tuned default block configurations produced by
the autotuner (see EXPERIMENTS.md §Paper-validation for the tuning runs)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as _attention
from . import covariance as _covariance
from . import matmul as _matmul
from . import ref
from . import ssd as _ssd
from . import syr2k as _syr2k


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad2(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def matmul(x, y, block_m: int = 256, block_n: int = 256, block_k: int = 512):
    m, n = x.shape[0], y.shape[1]
    bm, bn, bk = (min(block_m, m), min(block_n, n), min(block_k, x.shape[1]))
    xp = _pad2(x, bm, bk)
    yp = _pad2(y, bk, bn)
    out = _matmul.matmul(xp, yp, block_m=bm, block_n=bn, block_k=bk,
                         interpret=_interpret())
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "block_k"))
def syr2k(a, b, block_i: int = 256, block_j: int = 256, block_k: int = 512):
    n = a.shape[0]
    bi, bj, bk = min(block_i, n), min(block_j, n), min(block_k, a.shape[1])
    ap = _pad2(a, max(bi, bj), bk)
    bp = _pad2(b, max(bi, bj), bk)
    out = _syr2k.syr2k(ap, bp, block_i=bi, block_j=bj, block_k=bk,
                       interpret=_interpret())
    return out[:n, :n]


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "block_k"))
def covariance(data, block_i: int = 256, block_j: int = 256, block_k: int = 512):
    m = data.shape[1]
    bi, bj, bk = min(block_i, m), min(block_j, m), min(block_k, data.shape[0])
    dp = _pad2(data, bk, max(bi, bj))
    out = _covariance.covariance(dp, block_i=bi, block_j=bj, block_k=bk,
                                 interpret=_interpret())
    return out[:m, :m]


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv"))
def flash_attention(q, k, v, causal: bool = True,
                    block_q: int = 512, block_kv: int = 512):
    return _attention.flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a, b, c, chunk: int = 64):
    return _ssd.ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=_interpret())
