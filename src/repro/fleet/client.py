"""Fleet client: ``python -m repro.fleet.client submit|status|follow``.

The operator's hand on the dispatcher:

* ``submit spec.json`` — POST the spec; prints the job document (or the
  typed rejection — exit 2 on ``bad-spec``/``infeasible-space``, mirroring
  the session CLI's bad-spec exit code).  ``--follow`` tails the job to
  completion in one step.
* ``status [job_id]`` — the fleet summary, or one job's document.
* ``follow job_id`` — stream the job's NDJSON events until it is terminal
  (exit 0 on ``done``, 1 on ``failed``).

All subcommands take ``--connect host:port`` (default
``127.0.0.1:8757``).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Sequence

from .protocol import (DEFAULT_PORT, FleetError, http_json, http_lines,
                       iter_ndjson, parse_address)

__all__ = ["main", "submit", "follow"]


def submit(host: str, port: int, spec_doc: dict) -> dict:
    """POST one spec; returns the job document or raises
    :class:`~repro.fleet.protocol.FleetError` with the typed payload."""
    return http_json(host, port, "POST", "/submit", {"spec": spec_doc})


def follow(host: str, port: int, job_id: str):
    """Yield the job's event dicts until the stream closes (terminal job)."""
    yield from iter_ndjson(
        http_lines(host, port, "GET", f"/follow/{job_id}", timeout=None))


def _print(doc: dict) -> None:
    print(json.dumps(doc, indent=2, sort_keys=True, default=float))


def _follow_to_exit(host: str, port: int, job_id: str) -> int:
    last = None
    for ev in follow(host, port, job_id):
        print(json.dumps(ev, separators=(",", ":"), default=float),
              flush=True)
        last = ev
    if last is None:
        return 1
    if last.get("event") == "done":
        return 0
    return 1


def main(argv: "Sequence[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet.client",
        description="Talk to a fleet dispatcher: submit TuningSpec jobs, "
                    "inspect fleet state, follow result streams.")
    ap.add_argument("--connect", default=f"127.0.0.1:{DEFAULT_PORT}",
                    metavar="HOST:PORT", help="dispatcher address "
                    f"(default 127.0.0.1:{DEFAULT_PORT})")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_submit = sub.add_parser("submit", help="submit a TuningSpec JSON file")
    p_submit.add_argument("spec", metavar="SPEC.json",
                          help="TuningSpec document ('-' for stdin)")
    p_submit.add_argument("--follow", action="store_true",
                          help="after submitting, stream events until the "
                               "job is terminal")

    p_status = sub.add_parser("status", help="fleet summary or one job")
    p_status.add_argument("job_id", nargs="?", default=None)

    p_follow = sub.add_parser("follow", help="stream one job's events")
    p_follow.add_argument("job_id")

    args = ap.parse_args(argv)
    host, port = parse_address(args.connect)

    try:
        if args.cmd == "submit":
            if args.spec == "-":
                spec_doc = json.load(sys.stdin)
            else:
                with open(args.spec, encoding="utf-8") as fh:
                    spec_doc = json.load(fh)
            if not isinstance(spec_doc, dict):
                print("error: spec must be a JSON object", file=sys.stderr)
                return 2
            try:
                job = submit(host, port, spec_doc)
            except FleetError as e:
                _print(e.payload)
                return 2 if e.code in ("bad-spec", "infeasible-space") else 1
            _print(job)
            if args.follow:
                return _follow_to_exit(host, port, job["job_id"])
            return 0
        if args.cmd == "status":
            path = ("/status" if args.job_id is None
                    else f"/status/{args.job_id}")
            try:
                _print(http_json(host, port, "GET", path))
            except FleetError as e:
                _print(e.payload)
                return 1
            return 0
        if args.cmd == "follow":
            return _follow_to_exit(host, port, args.job_id)
    except BrokenPipeError:
        # stdout closed early (e.g. piped into `head`) — not a fleet error
        try:
            sys.stdout.close()
        except OSError:
            pass
        return 0
    except (ConnectionError, OSError) as e:
        print(f"error: dispatcher unreachable at {host}:{port} ({e})",
              file=sys.stderr)
        return 1
    return 2        # unreachable — argparse enforces the subcommand


if __name__ == "__main__":
    from repro.fleet.client import main as _canonical_main

    raise SystemExit(_canonical_main())
