"""Fleet dispatcher: ``python -m repro.fleet.server`` — tuning as a service.

The dispatcher is the transport leg of ROADMAP item 1: it accepts
:class:`~repro.core.session.TuningSpec` JSON submissions and store uploads,
**lints every spec at the door** (:func:`repro.analysis.lint.lint_spec` — a
spec that does not resolve, or whose sampled space is entirely statically
infeasible, is rejected with a typed error instead of burning a measurement
worker), queues jobs FIFO, hands them to pulling workers, streams NDJSON
experiment events to followers, and runs the **federation loop** — the
periodic :meth:`~repro.core.resultstore.ResultStore.merge` daemon PR 5 left
to the operator — so every worker's results land in one shared store and a
re-submitted (or subsumed) spec is answered from that cache with zero
backend dispatches.

Fault tolerance is inherited, not reinvented: a worker that stops
heartbeating has its job **requeued blindly with ``resume=True``** — the
session's crash-safe checkpoint sidecar (written under the dispatcher's
spool, so any local worker can pick it up) makes that safe even when no
checkpoint was written yet (``resume`` with a missing sidecar starts fresh).

All state lives in :class:`Dispatcher`, which is directly constructible for
in-process tests; :class:`FleetHTTPServer` is the thin
``ThreadingHTTPServer`` skin over it.  Stdlib only — sockets, threads,
``http.server``.
"""

from __future__ import annotations

import argparse
import itertools
import json
import logging
import os
import tempfile
import threading
import time
from dataclasses import dataclass, field
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Iterator, Sequence

from repro.core.resultstore import FederationDaemon, ResultStore
from repro.core.session import TuningSpec

from .protocol import HEARTBEAT_TIMEOUT_S, DEFAULT_PORT

__all__ = ["Dispatcher", "FleetHTTPServer", "Job", "main"]

_log = logging.getLogger("repro.fleet.server")

#: Job lifecycle: queued → running → done | failed (requeues go back to
#: queued with ``resume=True``).
JOB_STATES = ("queued", "running", "done", "failed")


@dataclass
class Job:
    """One submitted tuning job and everything followers can see of it."""

    job_id: str
    spec: dict                      # normalized TuningSpec document
    state: str = "queued"
    resume: bool = False            # requeued jobs resume from the sidecar
    worker_id: str | None = None
    requeues: int = 0
    submitted_at: float = field(default_factory=time.time)
    finished_at: float | None = None
    lint: dict | None = None
    events: list = field(default_factory=list)      # NDJSON event dicts
    result: dict | None = None      # terminal summary (best, counts, cache)
    log: dict | None = None         # full TuningLog dict from the worker
    error: str | None = None
    _exp_index: dict = field(default_factory=dict, repr=False)

    def record_event(self, ev: dict) -> None:
        """Record one streamed event; a re-delivered experiment number (a
        resumed job re-covering the window after its last checkpoint)
        replaces the original in place, so followers never see duplicates."""
        if ev.get("event") == "experiment" and "number" in ev:
            idx = self._exp_index.get(ev["number"])
            if idx is not None:
                self.events[idx] = ev
                return
            self._exp_index[ev["number"]] = len(self.events)
        self.events.append(ev)

    def public(self, with_events: bool = False) -> dict:
        out = {
            "job_id": self.job_id,
            "state": self.state,
            "workload": self.spec.get("workload"),
            "strategy": self.spec.get("strategy"),
            "backend": self.spec.get("backend"),
            "budget": self.spec.get("budget"),
            "worker": self.worker_id,
            "requeues": self.requeues,
            "submitted_at": self.submitted_at,
            "finished_at": self.finished_at,
            "lint": self.lint,
            "result": self.result,
            "error": self.error,
            "n_events": len(self.events),
        }
        if with_events:
            out["events"] = list(self.events)
        return out


@dataclass
class _Worker:
    worker_id: str
    name: str
    host: str = ""
    registered_at: float = field(default_factory=time.time)
    last_seen: float = field(default_factory=time.time)
    job_id: str | None = None
    jobs_done: int = 0
    dead: bool = False


class Dispatcher:
    """Queue + worker registry + federation — the fleet's single brain.

    One lock/condition guards all state; followers block on the condition
    and wake on every recorded event.  The shared federated store lives
    under ``spool_dir`` by default (``store_target`` overrides — a path or
    ``jsonl://``/``sqlite://`` URI); uploads are staged in
    ``spool/uploads.jsonl`` and folded in by the
    :class:`~repro.core.resultstore.FederationDaemon` every
    ``federation_interval_s`` seconds.
    """

    def __init__(
        self,
        spool_dir: "str | os.PathLike | None" = None,
        store_target: str | None = None,
        *,
        lint: bool = True,
        lint_samples: int = 200,
        heartbeat_timeout_s: float = HEARTBEAT_TIMEOUT_S,
        federation_interval_s: float = 2.0,
    ):
        self.spool_dir = os.path.abspath(
            os.fspath(spool_dir) if spool_dir
            else tempfile.mkdtemp(prefix="fleet_spool_"))
        os.makedirs(os.path.join(self.spool_dir, "jobs"), exist_ok=True)
        self.lint = lint
        self.lint_samples = int(lint_samples)
        self.heartbeat_timeout_s = float(heartbeat_timeout_s)

        self.store_target = (store_target
                             or os.path.join(self.spool_dir, "store.jsonl"))
        self.store = ResultStore.shared(self.store_target)
        self.uploads_path = os.path.join(self.spool_dir, "uploads.jsonl")
        self.federation = FederationDaemon(
            self.store, sources=[self.uploads_path],
            interval_s=federation_interval_s)
        self._uploads = ResultStore.shared(self.uploads_path)

        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._jobs: dict[str, Job] = {}
        self._queue: list[str] = []         # FIFO of queued job ids
        self._workers: dict[str, _Worker] = {}
        self._job_seq = itertools.count(1)
        self._worker_seq = itertools.count(1)
        self._closed = False
        self.started_at = time.time()

        self.federation.start()
        self._monitor = threading.Thread(
            target=self._monitor_loop, name="fleet-monitor", daemon=True)
        self._monitor.start()

    # -- client surface ------------------------------------------------------

    def submit(self, spec_doc: dict) -> dict:
        """Lint + enqueue one spec document.  Raises
        :class:`repro.analysis.lint.LintError` (typed: ``bad-spec`` /
        ``infeasible-space``) — the HTTP layer maps it to 400/422 so a bad
        spec never reaches a worker."""
        from repro.analysis.lint import lint_spec

        spec = TuningSpec.from_dict(spec_doc)   # raises ValueError → bad-spec
        report = None
        if self.lint:
            report = lint_spec(spec, samples=self.lint_samples)
        else:
            # even unlinted, the spec must resolve — that is the cheap half
            # of the door check and catches every "unknown name" mistake
            spec.build_space(spec.build_workload())
            spec.build_backend()
            spec.build_peers()
        with self._lock:
            job_id = f"j{next(self._job_seq):05d}"
            doc = spec.to_dict()
            if not doc.get("checkpoint"):
                # the sidecar under the spool is what makes blind requeue
                # safe: any local worker resumes a dead worker's job from it
                doc["checkpoint"] = os.path.join(
                    self.spool_dir, "jobs", f"{job_id}.ck.pkl")
            job = Job(job_id=job_id, spec=doc, lint=report)
            self._jobs[job_id] = job
            self._queue.append(job_id)
            job.record_event({"event": "queued", "job_id": job_id})
            self._cond.notify_all()
        _log.info("submitted %s (%s/%s on %s)", job_id, job.spec["workload"],
                  job.spec["strategy"], job.spec["backend"])
        return job.public()

    def job_status(self, job_id: str) -> "dict | None":
        with self._lock:
            job = self._jobs.get(job_id)
            return job.public() if job else None

    def status(self) -> dict:
        with self._lock:
            by_state: dict[str, int] = {}
            for j in self._jobs.values():
                by_state[j.state] = by_state.get(j.state, 0) + 1
            return {
                "uptime_s": round(time.time() - self.started_at, 3),
                "jobs": {jid: j.public() for jid, j in self._jobs.items()},
                "jobs_by_state": by_state,
                "queued": list(self._queue),
                "workers": {
                    w.worker_id: {
                        "name": w.name, "host": w.host, "job": w.job_id,
                        "jobs_done": w.jobs_done, "dead": w.dead,
                        "last_seen_age_s": round(time.time() - w.last_seen, 3),
                    } for w in self._workers.values()},
                "store": {"target": self.store_target,
                          "records": self.store.count()},
                "federation": self.federation.stats(),
            }

    def follow(self, job_id: str, timeout_s: "float | None" = None
               ) -> Iterator[dict]:
        """Yield the job's events from the beginning, then live as they land,
        until the job is terminal (a final synthetic ``done``/``failed``
        event closes the stream).  Not found yields a single error event."""
        deadline = None if timeout_s is None else time.time() + timeout_s
        sent = 0
        while True:
            with self._lock:
                job = self._jobs.get(job_id)
                if job is None:
                    yield {"event": "error", "error": "not-found",
                           "detail": f"unknown job {job_id!r}"}
                    return
                fresh = job.events[sent:]
                sent = len(job.events)
                terminal = job.state in ("done", "failed")
                if not fresh and not terminal:
                    wait = (None if deadline is None
                            else max(0.0, deadline - time.time()))
                    if wait == 0.0 or self._closed:
                        yield {"event": "error", "error": "timeout"}
                        return
                    self._cond.wait(timeout=wait if wait is not None else 1.0)
                    continue
            for ev in fresh:
                yield ev
            if terminal:
                return

    def upload(self, lines: Sequence[str]) -> dict:
        """The store-upload path: canonical JSONL record lines land in the
        staging store; the federation daemon folds them into the shared
        store on its next cycle (``flush_federation`` forces it)."""
        stats = self._uploads.ingest_lines(lines)
        _log.info("upload: %s", stats)
        return stats

    def export_store_lines(self) -> list[str]:
        """The store-download path (``GET /store``): flush federation first
        so a worker warm-pulling right after an upload sees those records."""
        self.federation.merge_now()
        return self.store.export_lines()

    def flush_federation(self) -> "dict | None":
        return self.federation.merge_now()

    # -- worker surface ------------------------------------------------------

    def register_worker(self, name: str = "", host: str = "") -> dict:
        with self._lock:
            worker_id = f"w{next(self._worker_seq):04d}"
            self._workers[worker_id] = _Worker(
                worker_id=worker_id, name=name or worker_id, host=host)
        _log.info("worker %s (%s) registered", worker_id, name or worker_id)
        return {"worker_id": worker_id,
                "heartbeat_timeout_s": self.heartbeat_timeout_s,
                "store_target": self.store_target}

    def poll(self, worker_id: str) -> "dict | None":
        """Assign the oldest queued job to this worker (None when idle)."""
        with self._lock:
            w = self._workers.get(worker_id)
            if w is None or w.dead:
                # a requeue marked this worker dead (or it never registered):
                # make it re-register so stale ownership can never revive
                raise KeyError(f"unknown or dead worker {worker_id!r}")
            w.last_seen = time.time()
            if not self._queue:
                return None
            job = self._jobs[self._queue.pop(0)]
            job.state = "running"
            job.worker_id = worker_id
            w.job_id = job.job_id
            job.record_event({"event": "assigned", "job_id": job.job_id,
                              "worker": worker_id, "resume": job.resume})
            self._cond.notify_all()
            return {"job_id": job.job_id, "spec": dict(job.spec),
                    "resume": job.resume}

    def heartbeat(self, worker_id: str, job_id: "str | None" = None,
                  events: "Sequence[dict] | None" = None) -> dict:
        """Liveness + streamed experiment events.  Returns ``{"abort": True}``
        when the named job is no longer owned by this worker (it was
        requeued after a missed deadline) — the worker should drop it."""
        with self._lock:
            w = self._workers.get(worker_id)
            if w is None:
                return {"abort": True}
            w.last_seen = time.time()
            job = self._jobs.get(job_id) if job_id else None
            owned = (job is not None and job.state == "running"
                     and job.worker_id == worker_id)
            if job is not None and owned and events:
                for ev in events:
                    if isinstance(ev, dict):
                        job.record_event(ev)
                self._cond.notify_all()
            return {"abort": bool(job_id) and not owned}

    def done(self, worker_id: str, job_id: str, *,
             ok: bool, log: "dict | None" = None,
             events: "Sequence[dict] | None" = None,
             error: "str | None" = None) -> dict:
        """Terminal job report from a worker."""
        with self._lock:
            job = self._jobs.get(job_id)
            w = self._workers.get(worker_id)
            if w is not None:
                w.last_seen = time.time()
                if w.job_id == job_id:
                    w.job_id = None
                    w.jobs_done += 1
            if job is None:
                return {"ok": False, "detail": f"unknown job {job_id!r}"}
            if job.worker_id != worker_id or job.state != "running":
                # a requeued job's original worker finishing late: its
                # report is stale — the requeue owns the truth now
                return {"ok": False, "detail": "job not owned"}
            for ev in events or ():
                if isinstance(ev, dict):
                    job.record_event(ev)
            job.state = "done" if ok else "failed"
            job.finished_at = time.time()
            job.log = log
            job.error = error
            if log and isinstance(log.get("experiments"), list):
                exps = log["experiments"]
                oks = [e for e in exps
                       if e.get("status") == "ok"
                       and e.get("time_s") is not None]
                best = (min(oks, key=lambda e: e["time_s"]) if oks else None)
                job.result = {
                    "experiments": len(exps),
                    "best": ({"number": best["number"],
                              "time_s": best["time_s"]} if best else None),
                    "cache": log.get("cache"),
                }
            job.record_event({"event": job.state, "job_id": job_id,
                              "worker": worker_id, "error": error,
                              "result": job.result})
            self._cond.notify_all()
        _log.info("job %s %s (worker %s)", job_id,
                  "done" if ok else f"failed: {error}", worker_id)
        return {"ok": True}

    # -- supervision ---------------------------------------------------------

    def _monitor_loop(self) -> None:
        interval = max(0.05, self.heartbeat_timeout_s / 4.0)
        while not self._closed:
            time.sleep(interval)
            self.requeue_dead()

    def requeue_dead(self) -> list[str]:
        """Requeue every running job whose worker missed the heartbeat
        deadline — blindly resumable: the job re-enters the queue with
        ``resume=True`` and the next worker continues from the checkpoint
        sidecar (or starts fresh if none was written)."""
        requeued: list[str] = []
        now = time.time()
        with self._lock:
            for job in self._jobs.values():
                if job.state != "running":
                    continue
                w = self._workers.get(job.worker_id or "")
                if w is not None and now - w.last_seen <= \
                        self.heartbeat_timeout_s:
                    continue
                if w is not None:
                    w.dead = True
                    w.job_id = None
                job.state = "queued"
                job.worker_id = None
                job.resume = True
                job.requeues += 1
                self._queue.append(job.job_id)
                job.record_event({"event": "requeued", "job_id": job.job_id,
                                  "resume": True, "requeues": job.requeues})
                requeued.append(job.job_id)
            if requeued:
                self._cond.notify_all()
        for jid in requeued:
            _log.warning("job %s requeued (worker heartbeat missed)", jid)
        return requeued

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._cond.notify_all()
        self.federation.stop(final_merge=True)

    def __enter__(self) -> "Dispatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# The HTTP skin
# ---------------------------------------------------------------------------


class _Handler(BaseHTTPRequestHandler):
    # HTTP/1.0 + connection-close framing: every request is its own
    # connection, streams end by EOF — no chunked-encoding bookkeeping.
    server_version = "repro-fleet/1.0"

    @property
    def dispatcher(self) -> Dispatcher:
        return self.server.dispatcher    # type: ignore[attr-defined]

    def log_message(self, fmt, *args):      # noqa: A003 — stdlib signature
        _log.debug("%s %s", self.address_string(), fmt % args)

    def _send_json(self, payload: dict, status: int = 200) -> None:
        body = json.dumps(payload, separators=(",", ":"),
                          default=float).encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _read_body(self) -> bytes:
        length = int(self.headers.get("Content-Length") or 0)
        return self.rfile.read(length) if length else b""

    def _read_json(self) -> dict:
        raw = self._read_body()
        if not raw:
            return {}
        obj = json.loads(raw.decode("utf-8"))
        if not isinstance(obj, dict):
            raise ValueError("request body must be a JSON object")
        return obj

    # -- routes --------------------------------------------------------------

    def do_GET(self) -> None:       # noqa: N802 — stdlib naming
        try:
            if self.path == "/status":
                self._send_json(self.dispatcher.status())
            elif self.path.startswith("/status/"):
                doc = self.dispatcher.job_status(self.path[len("/status/"):])
                if doc is None:
                    self._send_json({"error": "not-found",
                                     "detail": self.path}, status=404)
                else:
                    self._send_json(doc)
            elif self.path.startswith("/follow/"):
                self._stream_follow(self.path[len("/follow/"):])
            elif self.path == "/store":
                lines = self.dispatcher.export_store_lines()
                body = ("\n".join(lines) + ("\n" if lines else "")
                        ).encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", "application/x-ndjson")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
            else:
                self._send_json({"error": "not-found",
                                 "detail": self.path}, status=404)
        except (BrokenPipeError, ConnectionResetError):
            pass        # follower went away — nothing to clean up
        except Exception as e:      # noqa: BLE001 — surface, don't crash
            self._safe_error(e)

    def do_POST(self) -> None:      # noqa: N802 — stdlib naming
        from repro.analysis.lint import LintError

        d = self.dispatcher
        try:
            if self.path == "/submit":
                req = self._read_json()
                spec = req.get("spec")
                if not isinstance(spec, dict):
                    self._send_json({"error": "bad-spec",
                                     "detail": "body must be "
                                               "{\"spec\": {...}}"},
                                    status=400)
                    return
                try:
                    self._send_json(d.submit(spec))
                except LintError as e:
                    self._send_json(e.to_dict(),
                                    status=400 if e.code == "bad-spec"
                                    else 422)
                except (ValueError, TypeError) as e:
                    self._send_json({"error": "bad-spec", "detail": str(e)},
                                    status=400)
            elif self.path == "/upload":
                text = self._read_body().decode("utf-8", "replace")
                self._send_json(d.upload(text.splitlines()))
            elif self.path == "/worker/register":
                req = self._read_json()
                self._send_json(d.register_worker(
                    name=str(req.get("name", "")),
                    host=str(req.get("host", ""))))
            elif self.path == "/worker/poll":
                req = self._read_json()
                try:
                    job = d.poll(str(req.get("worker_id", "")))
                except KeyError as e:
                    self._send_json({"error": "unknown-worker",
                                     "detail": str(e)}, status=410)
                    return
                self._send_json({"job": job})
            elif self.path == "/worker/heartbeat":
                req = self._read_json()
                self._send_json(d.heartbeat(
                    str(req.get("worker_id", "")),
                    job_id=req.get("job_id"),
                    events=req.get("events") or []))
            elif self.path == "/worker/done":
                req = self._read_json()
                self._send_json(d.done(
                    str(req.get("worker_id", "")),
                    str(req.get("job_id", "")),
                    ok=bool(req.get("ok")),
                    log=req.get("log"),
                    events=req.get("events") or [],
                    error=req.get("error")))
            else:
                self._send_json({"error": "not-found",
                                 "detail": self.path}, status=404)
        except (BrokenPipeError, ConnectionResetError):
            pass
        except Exception as e:      # noqa: BLE001
            self._safe_error(e)

    def _stream_follow(self, job_id: str) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.end_headers()      # no Content-Length: EOF ends the stream
        for ev in self.dispatcher.follow(job_id):
            self.wfile.write(json.dumps(
                ev, separators=(",", ":"), default=float).encode("utf-8")
                + b"\n")
            self.wfile.flush()

    def _safe_error(self, e: Exception) -> None:
        _log.exception("request failed: %s", self.path)
        try:
            self._send_json({"error": "internal",
                             "detail": f"{type(e).__name__}: {e}"},
                            status=500)
        except OSError:
            pass


class FleetHTTPServer(ThreadingHTTPServer):
    """The dispatcher behind a threading HTTP server.  ``with
    FleetHTTPServer(dispatcher, ("127.0.0.1", 0)) as srv:`` binds an
    ephemeral port (``srv.port``); ``serve_forever`` runs it."""

    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, dispatcher: Dispatcher,
                 address: tuple[str, int] = ("127.0.0.1", DEFAULT_PORT)):
        super().__init__(address, _Handler)
        self.dispatcher = dispatcher

    @property
    def port(self) -> int:
        return self.server_address[1]

    def server_close(self) -> None:
        super().server_close()
        self.dispatcher.close()


def main(argv: "Sequence[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet.server",
        description="Fleet dispatcher: accepts TuningSpec submissions and "
                    "store uploads, lints specs at the door, queues jobs "
                    "for pulling workers, streams NDJSON results, and runs "
                    "the periodic store-federation merge.")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=DEFAULT_PORT,
                    help=f"listen port (default {DEFAULT_PORT}; 0 = "
                         f"ephemeral, printed on startup)")
    ap.add_argument("--spool", default=None, metavar="DIR",
                    help="spool directory for job sidecars, the shared "
                         "store, and upload staging (default: a fresh "
                         "temp dir)")
    ap.add_argument("--store", default=None, metavar="TARGET",
                    help="federated store target (path or jsonl:// / "
                         "sqlite:// URI; default <spool>/store.jsonl)")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the static door lint (specs must still "
                         "resolve)")
    ap.add_argument("--lint-samples", type=int, default=200,
                    help="schedules the door lint samples per spec "
                         "(default 200)")
    ap.add_argument("--heartbeat-timeout", type=float,
                    default=HEARTBEAT_TIMEOUT_S, metavar="S",
                    help="requeue a running job after S seconds without a "
                         f"worker heartbeat (default {HEARTBEAT_TIMEOUT_S})")
    ap.add_argument("--federation-interval", type=float, default=2.0,
                    metavar="S",
                    help="seconds between federation merge cycles "
                         "(default 2.0)")
    args = ap.parse_args(argv)

    logging.basicConfig(
        level=logging.INFO,
        format="[%(asctime)s] %(name)s %(levelname)s: %(message)s")
    dispatcher = Dispatcher(
        spool_dir=args.spool, store_target=args.store,
        lint=not args.no_lint, lint_samples=args.lint_samples,
        heartbeat_timeout_s=args.heartbeat_timeout,
        federation_interval_s=args.federation_interval)
    srv = FleetHTTPServer(dispatcher, (args.host, args.port))
    print(f"[fleet.server] listening on {args.host}:{srv.port} "
          f"(spool {dispatcher.spool_dir}, store {dispatcher.store_target})",
          flush=True)
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        srv.server_close()
    return 0


if __name__ == "__main__":
    from repro.fleet.server import main as _canonical_main

    raise SystemExit(_canonical_main())
