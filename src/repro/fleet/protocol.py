"""Fleet wire protocol: JSON over HTTP, NDJSON for streams — stdlib only.

Every fleet endpoint speaks JSON request/response bodies over plain HTTP
(:mod:`http.client` on the caller side, :mod:`http.server` in the
dispatcher); the two streaming surfaces — ``GET /follow/<job>`` result
streams and the ``GET /store`` / ``POST /upload`` store-transfer pair — are
newline-delimited (NDJSON / canonical store JSONL lines).  This module holds
the pieces every side shares: the request helper, NDJSON iteration, address
parsing, and the protocol defaults.

Routes (all rooted at the dispatcher):

=======================  ====================================================
``POST /submit``         body ``{"spec": {...TuningSpec...}}`` → job document
                         (typed error on bad/infeasible specs — see
                         :class:`repro.analysis.lint.LintError`)
``GET  /status``         fleet summary (jobs by state, workers, federation)
``GET  /status/<job>``   one job document
``GET  /follow/<job>``   NDJSON event stream until the job is terminal
``POST /upload``         canonical store JSONL lines → federated store intake
``GET  /store``          the federated store as canonical JSONL lines
``POST /worker/register``  worker hello → ``{"worker_id": ...}``
``POST /worker/poll``    → ``{"job": null | {job_id, spec, resume}}``
``POST /worker/heartbeat``  liveness + streamed experiment events
``POST /worker/done``    terminal job report (full TuningLog dict)
=======================  ====================================================
"""

from __future__ import annotations

import http.client
import json
from typing import Iterable, Iterator

__all__ = [
    "DEFAULT_PORT",
    "HEARTBEAT_INTERVAL_S",
    "HEARTBEAT_TIMEOUT_S",
    "FleetError",
    "http_json",
    "http_lines",
    "iter_ndjson",
    "parse_address",
]

DEFAULT_PORT = 8757
#: How often a busy worker reports liveness (and flushes streamed events).
HEARTBEAT_INTERVAL_S = 0.5
#: Dispatcher-side deadline: a running job whose worker has not heartbeat
#: within this window is requeued (blindly resumable — the checkpoint
#: sidecar makes ``--resume`` safe even if none was written yet).
HEARTBEAT_TIMEOUT_S = 5.0


class FleetError(RuntimeError):
    """A dispatcher-reported error, carrying the HTTP status and the typed
    payload (``{"error": code, "detail": ...}``) so callers can branch on
    ``code`` instead of parsing prose."""

    def __init__(self, status: int, payload: dict):
        self.status = status
        self.payload = payload if isinstance(payload, dict) else {}
        code = self.payload.get("error", "error")
        detail = self.payload.get("detail", "")
        super().__init__(f"{code} (HTTP {status}): {detail}")

    @property
    def code(self) -> str:
        return str(self.payload.get("error", "error"))


def parse_address(addr: str) -> tuple[str, int]:
    """``"host:port"`` / ``"host"`` / ``":port"`` → (host, port)."""
    addr = addr.strip()
    if ":" in addr:
        host, _, port = addr.rpartition(":")
        return (host or "127.0.0.1", int(port))
    return (addr or "127.0.0.1", DEFAULT_PORT)


def _request(host: str, port: int, method: str, path: str,
             body: "bytes | None" = None,
             content_type: str = "application/json",
             timeout: "float | None" = 30.0) -> "http.client.HTTPResponse":
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    headers = {"Content-Type": content_type} if body is not None else {}
    conn.request(method, path, body=body, headers=headers)
    resp = conn.getresponse()
    # the caller owns the response; the connection closes with it
    resp._fleet_conn = conn  # type: ignore[attr-defined]
    return resp


def http_json(host: str, port: int, method: str, path: str,
              payload: "dict | None" = None,
              timeout: "float | None" = 30.0) -> dict:
    """One JSON request/response round trip; raises :class:`FleetError` on a
    non-2xx status (with the decoded error payload when the body is JSON)."""
    body = (None if payload is None
            else json.dumps(payload, separators=(",", ":")).encode("utf-8"))
    resp = _request(host, port, method, path, body=body, timeout=timeout)
    try:
        raw = resp.read()
    finally:
        resp.close()
        resp._fleet_conn.close()  # type: ignore[attr-defined]
    try:
        data = json.loads(raw.decode("utf-8")) if raw else {}
    except ValueError:
        data = {"error": "bad-response", "detail": raw[:200].decode(
            "utf-8", "replace")}
    if not (200 <= resp.status < 300):
        raise FleetError(resp.status, data)
    return data if isinstance(data, dict) else {"value": data}


def http_lines(host: str, port: int, method: str, path: str,
               lines: "Iterable[str] | None" = None,
               timeout: "float | None" = None) -> Iterator[str]:
    """A line-streaming round trip: optionally send ``lines`` as the NDJSON
    body, then yield the response's non-empty lines as they arrive (the
    ``/follow`` and ``/store`` surfaces).  Raises :class:`FleetError` on a
    non-2xx status."""
    body = None
    if lines is not None:
        body = ("\n".join(lines) + "\n").encode("utf-8")
    resp = _request(host, port, method, path, body=body,
                    content_type="application/x-ndjson", timeout=timeout)
    if not (200 <= resp.status < 300):
        raw = resp.read()
        resp.close()
        resp._fleet_conn.close()  # type: ignore[attr-defined]
        try:
            data = json.loads(raw.decode("utf-8"))
        except ValueError:
            data = {"error": "bad-response"}
        raise FleetError(resp.status, data)
    try:
        for raw_line in resp:
            line = raw_line.decode("utf-8").strip()
            if line:
                yield line
    finally:
        resp.close()
        resp._fleet_conn.close()  # type: ignore[attr-defined]


def iter_ndjson(lines: Iterable[str]) -> Iterator[dict]:
    """Decode an NDJSON line stream, skipping blank/corrupt lines (stream
    tolerance mirrors the store's corruption tolerance)."""
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            obj = json.loads(line)
        except ValueError:
            continue
        if isinstance(obj, dict):
            yield obj
