"""Tuning as a service: fleet dispatcher, workers, client (ROADMAP item 1).

Stdlib-only (sockets, threads, ``http.server``) distribution layer over the
unchanged single-host stack:

* :mod:`repro.fleet.server` — the dispatcher (``python -m
  repro.fleet.server``): lints :class:`~repro.core.session.TuningSpec`
  submissions at the door via :func:`repro.analysis.lint.lint_spec`, queues
  jobs FIFO, streams NDJSON experiment events to followers, requeues jobs
  whose worker stops heartbeating (blindly resumable via the checkpoint
  sidecar), and runs the federation loop — the periodic
  :meth:`~repro.core.resultstore.ResultStore.merge` daemon that folds
  worker uploads into one shared store so re-submitted or subsumed specs
  are answered from cache with zero backend dispatches.
* :mod:`repro.fleet.worker` — ``python -m repro.fleet.worker --connect
  host:port``: pulls jobs, runs them through the unchanged
  :class:`~repro.core.session.TuningSession`, heartbeats, federates
  results.
* :mod:`repro.fleet.client` — ``python -m repro.fleet.client
  submit|status|follow``.
* :mod:`repro.fleet.protocol` — the shared JSON/NDJSON-over-HTTP wire
  helpers and route table.
"""

from .protocol import (DEFAULT_PORT, HEARTBEAT_INTERVAL_S,
                       HEARTBEAT_TIMEOUT_S, FleetError, http_json,
                       http_lines, iter_ndjson, parse_address)
from .server import Dispatcher, FleetHTTPServer, Job
from .worker import FleetWorker

__all__ = [
    "DEFAULT_PORT",
    "Dispatcher",
    "FleetError",
    "FleetHTTPServer",
    "FleetWorker",
    "HEARTBEAT_INTERVAL_S",
    "HEARTBEAT_TIMEOUT_S",
    "Job",
    "http_json",
    "http_lines",
    "iter_ndjson",
    "parse_address",
]
