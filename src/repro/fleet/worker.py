"""Fleet worker: ``python -m repro.fleet.worker --connect host:port``.

A worker is deliberately thin: it registers, pulls jobs from the
dispatcher, and runs each one through the **unchanged**
:class:`~repro.core.session.TuningSession` stack (spec → ``spec.run``),
so every session feature — retries, quarantine, checkpoints, surrogate,
async pipeline — works identically under the fleet.  While a job runs, a
heartbeat thread reports liveness every
:data:`~repro.fleet.protocol.HEARTBEAT_INTERVAL_S` seconds and flushes the
experiment events the session streamed since the last beat; if the worker
dies (kill -9 included), the dispatcher notices the silence and requeues
the job with ``resume=True`` against its checkpoint sidecar.

Warm starts come from the federation: a job whose spec leaves ``store``
unset (``null``) gets a worker-local store that is first primed from
``GET /store`` — so a re-submitted spec replays entirely from cached
records, with zero backend dispatches — and is uploaded back
(``POST /upload``) when the job finishes.  A spec that pins ``store`` to a
path, or opts out with ``false``, is left alone.
"""

from __future__ import annotations

import argparse
import logging
import os
import queue
import socket
import tempfile
import threading
import time
from typing import Sequence

from repro.core.autotuner import NoSuccessfulExperiment
from repro.core.resultstore import ResultStore
from repro.core.session import TuningSpec

from .protocol import (HEARTBEAT_INTERVAL_S, FleetError, http_json,
                       http_lines, parse_address)

__all__ = ["FleetWorker", "main"]

_log = logging.getLogger("repro.fleet.worker")


class FleetWorker:
    """One polling measurement host.  ``run_forever`` is the CLI loop;
    ``run_one`` (poll + execute a single job, False when the queue was
    empty) is the test surface."""

    def __init__(self, host: str, port: int, *, name: str = "",
                 workdir: "str | None" = None,
                 store_path: "str | None" = None,
                 poll_interval_s: float = 0.2,
                 heartbeat_interval_s: float = HEARTBEAT_INTERVAL_S):
        self.host, self.port = host, port
        self.name = name or f"{socket.gethostname()}-{os.getpid()}"
        self.workdir = os.path.abspath(
            workdir or tempfile.mkdtemp(prefix="fleet_worker_"))
        os.makedirs(self.workdir, exist_ok=True)
        self.store_path = store_path or os.path.join(
            self.workdir, "store.jsonl")
        self.poll_interval_s = float(poll_interval_s)
        self.heartbeat_interval_s = float(heartbeat_interval_s)
        self.worker_id: "str | None" = None
        self.jobs_done = 0

    # -- dispatcher round trips ---------------------------------------------

    def _call(self, path: str, payload: dict) -> dict:
        return http_json(self.host, self.port, "POST", path, payload)

    def register(self) -> str:
        hello = self._call("/worker/register",
                           {"name": self.name,
                            "host": socket.gethostname()})
        self.worker_id = hello["worker_id"]
        _log.info("registered as %s (%s)", self.worker_id, self.name)
        return self.worker_id

    def _poll(self) -> "dict | None":
        if self.worker_id is None:
            self.register()
        try:
            return self._call("/worker/poll",
                              {"worker_id": self.worker_id})["job"]
        except FleetError as e:
            if e.code == "unknown-worker":
                # the dispatcher declared us dead (a requeue won the race,
                # or it restarted) — re-register and try again next tick
                _log.warning("dispatcher dropped us (%s); re-registering", e)
                self.worker_id = None
                return None
            raise

    def pull_warm_store(self) -> dict:
        """Prime the worker-local store from the federated one."""
        local = ResultStore.shared(self.store_path)
        lines = list(http_lines(self.host, self.port, "GET", "/store",
                                timeout=30.0))
        stats = local.ingest_lines(lines)
        _log.info("warm store pull: %s", stats)
        return stats

    def push_store(self) -> dict:
        """Upload the worker-local store into the federation intake."""
        local = ResultStore.shared(self.store_path)
        lines = local.export_lines()
        if not lines:
            return {"ingested": 0, "skipped": 0, "corrupt": 0}
        # /upload takes the raw JSONL body, not a JSON object
        for _ in http_lines(self.host, self.port, "POST", "/upload",
                            lines=lines):
            pass
        _log.info("uploaded %d store lines", len(lines))
        return {"uploaded": len(lines)}

    # -- job execution -------------------------------------------------------

    def run_one(self) -> bool:
        """Poll once; run the job if one was assigned.  Returns whether a
        job was executed (False = queue empty)."""
        job = self._poll()
        if job is None:
            return False
        self._execute(job)
        self.jobs_done += 1
        return True

    def _execute(self, job: dict) -> None:
        job_id = job["job_id"]
        resume = bool(job.get("resume"))
        doc = dict(job["spec"])
        _log.info("job %s: %s/%s on %s (budget %s%s)", job_id,
                  doc.get("workload"), doc.get("strategy"),
                  doc.get("backend"), doc.get("budget"),
                  ", resume" if resume else "")

        # federation store policy: an unset store gets the worker-local one,
        # warm-primed from the dispatcher; False / explicit targets are the
        # spec author's call and stay untouched.
        if doc.get("store") is None:
            doc["store"] = self.store_path
            try:
                self.pull_warm_store()
            except (FleetError, OSError) as e:
                _log.warning("warm store pull failed (%s) — running cold", e)

        events: "queue.SimpleQueue[dict]" = queue.SimpleQueue()
        stop_beats = threading.Event()

        def on_experiment(exp) -> None:
            events.put({"event": "experiment", **exp.to_dict()})

        def drain() -> list[dict]:
            out: list[dict] = []
            while True:
                try:
                    out.append(events.get_nowait())
                except queue.Empty:
                    return out

        def beat_loop() -> None:
            while not stop_beats.wait(self.heartbeat_interval_s):
                try:
                    resp = self._call("/worker/heartbeat",
                                      {"worker_id": self.worker_id,
                                       "job_id": job_id,
                                       "events": drain()})
                except (FleetError, OSError) as e:
                    _log.warning("heartbeat failed: %s", e)
                    continue
                if resp.get("abort"):
                    # the job was requeued away from us; keep quiet — our
                    # eventual done-report will be rejected as stale
                    _log.warning("job %s no longer ours — "
                                 "dispatcher requeued it", job_id)
                    return

        beats = threading.Thread(target=beat_loop,
                                 name=f"fleet-heartbeat-{job_id}",
                                 daemon=True)
        beats.start()
        ok, log_doc, error = False, None, None
        try:
            spec = TuningSpec.from_dict(doc)
            log = spec.run(on_experiment, resume=resume)
            log_doc, ok = log.to_dict(), True
        except NoSuccessfulExperiment as e:
            error = f"all experiments failed: {e}"
        except Exception as e:      # noqa: BLE001 — report, stay alive
            _log.exception("job %s crashed in-session", job_id)
            error = f"{type(e).__name__}: {e}"
        finally:
            stop_beats.set()
            beats.join(timeout=5.0)

        if doc.get("store") == self.store_path:
            try:
                self.push_store()
            except (FleetError, OSError) as e:
                _log.warning("store upload failed: %s", e)
        try:
            self._call("/worker/done",
                       {"worker_id": self.worker_id, "job_id": job_id,
                        "ok": ok, "log": log_doc, "events": drain(),
                        "error": error})
        except (FleetError, OSError) as e:
            _log.warning("done report failed: %s", e)

    def run_forever(self, max_jobs: "int | None" = None) -> int:
        self.register()
        while max_jobs is None or self.jobs_done < max_jobs:
            try:
                if not self.run_one():
                    time.sleep(self.poll_interval_s)
            except (FleetError, OSError) as e:
                _log.warning("dispatcher unreachable (%s); retrying", e)
                time.sleep(max(self.poll_interval_s, 0.5))
        return self.jobs_done


def main(argv: "Sequence[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet.worker",
        description="Fleet measurement worker: pulls jobs from the "
                    "dispatcher, runs them through the unchanged "
                    "TuningSession, heartbeats, and federates results.")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT",
                    help="dispatcher address")
    ap.add_argument("--name", default="",
                    help="worker display name (default host-pid)")
    ap.add_argument("--workdir", default=None, metavar="DIR",
                    help="scratch dir for the worker-local store "
                         "(default: a fresh temp dir)")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="worker-local store path "
                         "(default <workdir>/store.jsonl)")
    ap.add_argument("--max-jobs", type=int, default=None, metavar="N",
                    help="exit after N jobs (default: run forever)")
    ap.add_argument("--poll-interval", type=float, default=0.2, metavar="S",
                    help="idle poll period in seconds (default 0.2)")
    ap.add_argument("--heartbeat-interval", type=float,
                    default=HEARTBEAT_INTERVAL_S, metavar="S",
                    help="heartbeat/event-flush period "
                         f"(default {HEARTBEAT_INTERVAL_S})")
    args = ap.parse_args(argv)

    logging.basicConfig(
        level=logging.INFO,
        format="[%(asctime)s] %(name)s %(levelname)s: %(message)s")
    host, port = parse_address(args.connect)
    worker = FleetWorker(host, port, name=args.name, workdir=args.workdir,
                         store_path=args.store,
                         poll_interval_s=args.poll_interval,
                         heartbeat_interval_s=args.heartbeat_interval)
    try:
        done = worker.run_forever(max_jobs=args.max_jobs)
    except KeyboardInterrupt:
        done = worker.jobs_done
    print(f"[fleet.worker] {worker.name}: {done} job(s) done", flush=True)
    return 0


if __name__ == "__main__":
    from repro.fleet.worker import main as _canonical_main

    raise SystemExit(_canonical_main())
