"""Deterministic synthetic data pipeline.

Production posture without a corpus: every host derives its shard of each
global batch purely from (seed, step, host) via counter-based hashing, so

* any host can be restarted and regenerate exactly its shard (fault tolerance),
* the global batch is identical regardless of host count (elastic re-sharding),
* a background prefetch thread keeps the accelerator fed.

The token stream is Zipf-distributed with injected n-gram structure so the
model has something learnable (losses visibly fall during the e2e example).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    n_hosts: int = 1
    host_id: int = 0


def _philox(seed: int, step: int, row: int) -> np.random.Generator:
    # counter-based: independent stream per (seed, step, row)
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, row]))


def global_batch_rows(cfg: DataConfig, step: int, rows: range) -> np.ndarray:
    """Rows [rows) of the global batch at `step`: (len(rows), seq_len+1)."""
    out = np.empty((len(rows), cfg.seq_len + 1), np.int32)
    for i, r in enumerate(rows):
        rng = _philox(cfg.seed, step, r)
        # Zipf body clipped to vocab
        toks = rng.zipf(cfg.zipf_a, cfg.seq_len + 1).astype(np.int64)
        toks = (toks - 1) % cfg.vocab_size
        # inject learnable bigram structure: even positions repeat a motif
        motif = rng.integers(0, cfg.vocab_size, 8)
        idx = np.arange(cfg.seq_len + 1)
        mask = (idx % 7) < 3
        toks[mask] = motif[idx[mask] % 8]
        out[i] = toks.astype(np.int32)
    return out


def host_batch(cfg: DataConfig, step: int) -> np.ndarray:
    """This host's contiguous shard of the global batch."""
    per = cfg.global_batch // cfg.n_hosts
    start = cfg.host_id * per
    return global_batch_rows(cfg, step, range(start, start + per))


class Prefetcher:
    """Background thread producing host batches a few steps ahead."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 4):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = host_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
