"""Roofline-term extraction from compiled XLA artifacts.

Per (arch × shape × mesh) the dry-run produces:

  compute term    = HLO_FLOPs / (chips × 197 TFLOP/s)
  memory term     = HLO_bytes / (chips × 819 GB/s)
  collective term = wire_bytes / (chips × 50 GB/s ICI)

Caveats handled here (verified empirically in this repo):

* ``compiled.cost_analysis()`` reports **per-device** numbers and counts a
  ``while`` (scan) body **once**, so totals are stitched from two lowerings:
  the full step (memory analysis + non-layer cost) and a single layer with the
  same shardings (per-layer cost), giving ``total = full + (L-1)·layer``.
  Alternatively :func:`hlo_collectives` multiplies ops inside while-body
  computations by the trip count.
* collective bytes are not in cost_analysis: we parse the optimized HLO text,
  sum operand sizes of every collective op, convert to wire bytes with the
  standard algorithm factors (ring all-gather/reduce-scatter: (g−1)/g, ring
  all-reduce: 2(g−1)/g, all-to-all: (g−1)/g², permute: 1), with the replica
  group size g parsed per-op.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from dataclasses import dataclass, field

# TPU v5e hardware constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s per link (conservative: 1 link per hop)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclass
class CollectiveOp:
    kind: str
    operand_bytes: float
    group_size: int
    computation: str

    @property
    def wire_bytes(self) -> float:
        g = max(self.group_size, 1)
        if self.kind == "all-gather":
            return self.operand_bytes * (g - 1)          # operand is the shard
        if self.kind == "reduce-scatter":
            return self.operand_bytes * (g - 1) / g
        if self.kind == "all-reduce":
            return 2 * self.operand_bytes * (g - 1) / g
        if self.kind == "all-to-all":
            return self.operand_bytes * (g - 1) / g
        return self.operand_bytes                        # collective-permute


def _shape_bytes(shape_str: str) -> float:
    """'bf16[8,128,1024]{...}' → bytes.  Tuples handled by caller."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0.0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n * nbytes)


def _group_size(line: str, total_devices: int) -> int:
    # iota format: replica_groups=[32,16]<=[512] → group size = second dim
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return int(m.group(2))
    # explicit: replica_groups={{0,1,2,...},{...}}
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\{\}", line)
    if m:
        return total_devices
    return total_devices


def _parse_computations(hlo: str) -> dict[str, list[str]]:
    """computation name → its op lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->.*{\s*$",
                     line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip().startswith("}"):
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _while_bodies(hlo: str) -> set[str]:
    return set(re.findall(r"body=%?([\w\.\-]+)", hlo))


def hlo_collectives(hlo: str, total_devices: int,
                    while_trips: int = 1) -> tuple[float, list[CollectiveOp]]:
    """Total per-device wire bytes of all collectives in the HLO text.

    Ops inside while-loop bodies (and computations they call, approximated by
    fusion inlining in optimized HLO) are multiplied by ``while_trips``.
    """
    comps = _parse_computations(hlo)
    bodies = _while_bodies(hlo)
    ops: list[CollectiveOp] = []
    total = 0.0
    for cname, lines in comps.items():
        mult = while_trips if cname in bodies else 1
        for line in lines:
            ls = line.strip()
            m = re.match(r"%?[\w\.\-]+ = (\([^=]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*) "
                         r"([a-z\-]+)", ls)
            if not m:
                continue
            shape_str, opname = m.groups()
            kind = next((c for c in _COLLECTIVES if opname.startswith(c)), None)
            if kind is None or opname.startswith("all-reduce-scatter"):
                continue
            # operand sizes: prefer result size for uniformity; for all-gather
            # use the per-shard operand (= result / g)
            if shape_str.startswith("("):
                sizes = [_shape_bytes(s.strip())
                         for s in shape_str[1:-1].split(",") if "[" in s]
                # tuple shapes list dtype[dims] fragments — rough rejoin
                sizes = [_shape_bytes(s) for s in re.findall(
                    r"[a-z0-9]+\[[0-9,]*\]", shape_str)]
                res_bytes = sum(sizes)
            else:
                res_bytes = _shape_bytes(shape_str)
            g = _group_size(ls, total_devices)
            if kind == "all-gather":
                operand = res_bytes / max(g, 1)
            else:
                operand = res_bytes
            op = CollectiveOp(kind=kind, operand_bytes=operand, group_size=g,
                              computation=cname)
            ops.append(op)
            total += op.wire_bytes * mult
    return total, ops


# ---------------------------------------------------------------------------


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device totals (stitched)
    flops: float
    hbm_bytes: float
    wire_bytes: float
    # memory analysis of the full step
    argument_bytes: int
    temp_bytes: int
    output_bytes: int
    model_flops_total: float        # 6·N·D (train) or 2·N·D (serve), global
    notes: str = ""

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/dispatch waste."""
        hlo_total = self.flops * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the step at peak: what MFU would be if
        the step ran exactly at the max roofline term."""
        ideal = self.model_flops_total / (self.chips * PEAK_FLOPS)
        return ideal / self.step_time_s if self.step_time_s else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 step_time_s=self.step_time_s,
                 useful_flops_fraction=self.useful_flops_fraction,
                 roofline_fraction=self.roofline_fraction)
        return d


def stitch(full: dict, layer: dict | None, n_layers: int) -> dict:
    """total = full + (L−1)·layer for per-device flops/bytes/wire_bytes.

    ``full`` counted the scanned layer once; adding (L−1) more layer costs
    yields the true per-step totals."""
    if layer is None:
        return dict(full)
    out = dict(full)
    for k in ("flops", "hbm_bytes", "wire_bytes"):
        out[k] = full.get(k, 0.0) + (n_layers - 1) * layer.get(k, 0.0)
    return out


def cost_summary(compiled, total_devices: int, while_trips: int = 1) -> dict:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    wire, _ = hlo_collectives(hlo, total_devices, while_trips=while_trips)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "hbm_bytes": float(ca.get("bytes accessed", 0.0)),
        "wire_bytes": wire,
        "argument_bytes": ma.argument_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
    }
