"""Shared layer library: norms, RoPE, GQA/local attention with KV caches,
gated MLPs, and the expert-parallel MoE block.

All layers are pure functions over explicit parameter pytrees (no framework),
cast activations to ``cfg.dtype`` and keep master params in ``cfg.param_dtype``.
Sharding is expressed through logical-axis constraints (see sharding.py).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import sharding as sh


def cdtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# initialisation helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if len(shape) == 3:            # (E, d_in, d_out) expert stacks
        fan_in = shape[1]
    s = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps=1e-5):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float, rotary_dim: int | None = None):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    rd = rotary_dim or hd
    half = rd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs       # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                             # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:rd].astype(jnp.float32)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([rot.astype(x.dtype), x[..., rd:]], axis=-1)


# ---------------------------------------------------------------------------
# attention (GQA, optional sliding window, prefill/decode caches)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array            # (B, S_max, KV, hd)
    v: jax.Array
    length: jax.Array       # (B,) — filled positions


def attn_params_init(key, cfg, d_model=None):
    d = d_model or cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dt),
        "wk": dense_init(ks[1], (d, KV * hd), dt),
        "wv": dense_init(ks[2], (d, KV * hd), dt),
        "wo": dense_init(ks[3], (H * hd, d), dt, scale=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((H * hd,), dt), bk=jnp.zeros((KV * hd,), dt),
                 bv=jnp.zeros((KV * hd,), dt))
    return p


def attn_axes(cfg):
    a = {"wq": ("fsdp", "heads"), "wk": ("fsdp", "kv_heads"),
         "wv": ("fsdp", "kv_heads"), "wo": ("heads", "fsdp")}
    if cfg.qkv_bias:
        a.update(bq=("heads",), bk=("kv_heads",), bv=("kv_heads",))
    return a


def _qkv(x, p, cfg):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    return q, k, v


def _sdpa(q, k, v, mask):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd), mask bool broadcastable to (B,Sq,Sk).

    Scores/probs stay in the compute dtype (bf16 in production configs) with
    f32 row statistics and f32 PV accumulation — the XLA analogue of a flash
    kernel's numerics without materialising an O(S²) f32 tensor (which is
    what blows the HBM budget at 4k+ sequence lengths)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    qs = (q * jnp.asarray(scale, q.dtype)).reshape(B, Sq, KV, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qs, k)            # compute dtype
    m = jnp.broadcast_to(mask, (B,) + mask.shape[1:])
    neg = jnp.asarray(-3e38 if s.dtype == jnp.float32 else -3e4, s.dtype)
    s = jnp.where(m[:, None, None, ...] if m.ndim == 3 else m, s, neg)
    smax = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - smax)
    # probs stay in the compute dtype end-to-end: an f32 row-sum would pull
    # the entire O(S²) backward chain into f32 (+converts) — measured 4×
    # HBM-traffic inflation.  Flash kernels also feed bf16 probs to the MXU.
    l = jnp.sum(p, axis=-1, keepdims=True)          # (B,KV,g,Sq,1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)       # unnormalised
    o = o / jnp.maximum(jnp.transpose(l, (0, 3, 1, 2, 4)), 1e-6)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def attention(x, p, cfg, positions, *, window: int = 0, cache: KVCache | None = None):
    """Returns (y, new_cache).  Train/prefill: cache=None builds causal (or
    windowed) self-attention and returns the fresh cache for serving.  Decode:
    S==1 step appended to the cache."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _qkv(x, p, cfg)
    if cfg.rope_theta:       # rope_theta=0 → absolute positions (whisper)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    # Shard attention by Q heads.  When kv heads don't cover the model axis
    # (GQA with small kv), replicate K/V heads instead of letting GSPMD split
    # the head_dim — that path triggers involuntary full rematerialisation.
    q = sh.constrain(q, "batch", "seq", "heads", None)
    kv_ok = KV % max(sh.axis_size("kv_heads"), 1) == 0
    k = sh.constrain(k, "batch", "seq", "kv_heads" if kv_ok else None, None)
    v = sh.constrain(v, "batch", "seq", "kv_heads" if kv_ok else None, None)

    if cache is None:
        bq = cfg.attn_q_chunk
        if bq and S > bq and S % bq == 0:
            # blockwise (flash-style) attention: tile the query loop — the
            # paper's Tile transformation applied to the attention nest.  The
            # per-block score tensor is (B, bq, ≤S); causal blocks also slice
            # KV to the block's horizon (static slices → exact HLO cost).
            outs = []
            for qi in range(S // bq):
                qb = q[:, qi * bq:(qi + 1) * bq]
                posb = positions[:, qi * bq:(qi + 1) * bq]
                hi = (qi + 1) * bq        # causal horizon of this block
                kb, vb = k[:, :hi], v[:, :hi]
                kposb = positions[:, None, :hi]
                mask = kposb <= posb[:, :, None]
                if window:
                    mask = mask & (kposb > posb[:, :, None] - window)
                outs.append(_sdpa(qb, kb, vb, mask))
            y = jnp.concatenate(outs, axis=1)
        else:
            qpos = positions[:, :, None]              # (B,S,1)
            kpos = positions[:, None, :]              # (B,1,S)
            mask = kpos <= qpos
            if window:
                mask = mask & (kpos > qpos - window)
            y = _sdpa(q, k, v, mask)
        new_cache = KVCache(k=k, v=v, length=jnp.full((B,), S, jnp.int32))
    else:
        # decode: append this step, attend over valid prefix
        idx = cache.length[0]                         # uniform fill pointer
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), idx, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), idx, axis=1)
        kc = sh.constrain(kc, "batch", "kv_seq", "kv_heads", None)
        vc = sh.constrain(vc, "batch", "kv_seq", "kv_heads", None)
        Smax = kc.shape[1]
        kpos = jnp.arange(Smax)[None, None, :]        # (1,1,Smax)
        valid = kpos <= idx
        if window:
            valid = valid & (kpos > idx - window)
        y = _sdpa(q, kc, vc, valid)
        new_cache = KVCache(k=kc, v=vc, length=cache.length + S)

    y = y.reshape(B, S, H * hd)
    y = y @ p["wo"].astype(y.dtype)
    return sh.constrain(y, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_params_init(key, cfg, d_ff=None, d_model=None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu":          # non-gated (whisper)
        return {"wi": dense_init(ks[0], (d, f), dt),
                "bi": jnp.zeros((f,), dt),
                "wo": dense_init(ks[1], (f, d), dt),
                "bo": jnp.zeros((d,), dt)}
    return {"gate": dense_init(ks[0], (d, f), dt),
            "up": dense_init(ks[1], (d, f), dt),
            "down": dense_init(ks[2], (f, d), dt)}


def mlp_axes(cfg):
    if cfg.act == "gelu":
        return {"wi": ("fsdp", "ff"), "bi": ("ff",),
                "wo": ("ff", "fsdp"), "bo": ("embed",)}
    return {"gate": ("fsdp", "ff"), "up": ("fsdp", "ff"),
            "down": ("ff", "fsdp")}


def mlp(x, p, cfg):
    dt = x.dtype
    if cfg.act == "gelu":
        h = jax.nn.gelu(x @ p["wi"].astype(dt) + p["bi"].astype(dt))
        return h @ p["wo"].astype(dt) + p["bo"].astype(dt)
    act = jax.nn.gelu if cfg.act == "gelu_gated" else jax.nn.silu
    h = act(x @ p["gate"].astype(dt)) * (x @ p["up"].astype(dt))
    h = sh.constrain(h, "batch", "seq", "ff")
    return h @ p["down"].astype(dt)


# ---------------------------------------------------------------------------
# Mixture of Experts (expert-parallel, capacity-factor dropping)
# ---------------------------------------------------------------------------


def moe_params_init(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    edt = jnp.dtype(cfg.expert_dtype) if cfg.expert_dtype else dt
    d, fm, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), dt, scale=0.02),
        "experts": {
            "gate": dense_init(ks[1], (E, d, fm), edt),
            "up": dense_init(ks[2], (E, d, fm), edt),
            "down": dense_init(ks[3], (E, fm, d), edt,
                               scale=1.0 / math.sqrt(fm)),
        },
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {"gate": dense_init(kss[0], (d, fs), dt),
                       "up": dense_init(kss[1], (d, fs), dt),
                       "down": dense_init(kss[2], (fs, d), dt)}
    return p


def moe_axes(cfg):
    a = {"router": ("embed", None),
         "experts": {"gate": ("experts", "fsdp", "moe_ff"),
                     "up": ("experts", "fsdp", "moe_ff"),
                     # down (E, fm, d): shard fm over fsdp so the shard_map
                     # body gathers every expert mat along axis=1 uniformly
                     "down": ("experts", "fsdp", None)}}
    if cfg.n_shared_experts:
        a["shared"] = {"gate": ("fsdp", "ff"), "up": ("fsdp", "ff"),
                       "down": ("ff", "fsdp")}
    return a


def _moe_local(x2d, router_w, we, cfg, ep_axis: str | None):
    """Token dispatch → (expert-parallel all_to_all) → grouped GEMM → combine.

    x2d: (T, D) local tokens.  we: expert weights, local shard (E_loc on dim 0)
    when ep_axis is set, full (E, ...) otherwise.  Returns (y (T,D), aux loss).
    """
    T, D = x2d.shape
    E, k = cfg.n_experts, cfg.top_k
    ep = 1
    if ep_axis is not None:
        ep = jax.lax.axis_size(ep_axis)
    E_loc = E // ep

    logits = (x2d @ router_w.astype(x2d.dtype)).astype(jnp.float32)   # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                            # (T,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss (local estimate; psum'd below)
    me = probs.mean(axis=0)                                           # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(
        1.0 / (T * k))
    aux = E * jnp.sum(me * ce)

    C = max(1, math.ceil(k * T / E * cfg.capacity_factor))

    flat_ids = top_i.reshape(-1)                                      # (T·k,)
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    counts = jnp.bincount(flat_ids, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(T * k) - starts[sorted_ids]
    keep = pos_sorted < C
    dest_sorted = jnp.where(keep, sorted_ids * C + pos_sorted, E * C)
    # slot of each (token, k) pair in flat order
    dest = jnp.zeros((T * k,), jnp.int32).at[order].set(
        dest_sorted.astype(jnp.int32))

    src_token = order // k
    buf = jnp.zeros((E * C, D), x2d.dtype).at[dest_sorted].set(
        x2d[src_token], mode="drop")
    buf = buf.reshape(E, C, D)

    if ep_axis is not None:
        # (E, C, D) = (ep·E_loc, C, D) → peers exchange expert shards:
        # receive (E_loc, ep·C, D)
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                                 tiled=True)

    act = jax.nn.silu
    h = act(jnp.einsum("ecd,edf->ecf", buf, we["gate"].astype(buf.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, we["up"].astype(buf.dtype))
    out = jnp.einsum("ecf,efd->ecd", h, we["down"].astype(buf.dtype))

    if ep_axis is not None:
        out = jax.lax.all_to_all(out, ep_axis, split_axis=1, concat_axis=0,
                                 tiled=True)                          # (E, C, D)
    out = out.reshape(E * C, D)
    out = jnp.concatenate([out, jnp.zeros((1, D), out.dtype)], axis=0)

    gathered = out[jnp.minimum(dest, E * C)]                          # (T·k, D)
    w_flat = top_w.reshape(-1, 1).astype(gathered.dtype)
    dropped = (dest == E * C)[:, None]
    y = jnp.where(dropped, 0.0, gathered * w_flat).reshape(T, k, D).sum(axis=1)

    if ep_axis is not None:
        aux = jax.lax.pmean(aux, ep_axis)
    return y, aux


def moe_ffn(x, p, cfg):
    """x: (B,S,D) → (y, aux_loss).  Uses expert-parallel shard_map when a mesh
    is installed, plain local dispatch otherwise (smoke tests)."""
    B, S, D = x.shape
    mesh = sh.mesh()
    ep_axis = None
    rules = sh.rules() or {}
    if mesh is not None:
        e = rules.get("experts")
        if isinstance(e, str):
            ep_axis = e

    if mesh is None or ep_axis is None:
        x2d = x.reshape(B * S, D)
        y, aux = _moe_local(x2d, p["router"], p["experts"], cfg, None)
        y = y.reshape(B, S, D)
    else:
        token_spec = sh.spec("batch", "seq", None)
        # tokens additionally split over the EP axis when seq allows
        seq_over_ep = S % mesh.shape[ep_axis] == 0 and S >= mesh.shape[ep_axis]
        if seq_over_ep and token_spec[1] is None:
            parts = list(token_spec)
            parts[1] = ep_axis
            token_spec = P(*parts)

        # expert weights are stored FSDP-sharded (ZeRO-3) over the data axes
        # and gathered transiently per layer inside the shard_map body
        fsdp = rules.get("fsdp")
        fsdp_axes = (fsdp,) if isinstance(fsdp, str) else tuple(fsdp or ())
        fsdp_axes = tuple(a for a in fsdp_axes if a in mesh.axis_names)

        def body(x_loc, router_w, we):
            b, s, d = x_loc.shape
            if fsdp_axes:
                we = {
                    "gate": jax.lax.all_gather(we["gate"], fsdp_axes, axis=1,
                                               tiled=True),
                    "up": jax.lax.all_gather(we["up"], fsdp_axes, axis=1,
                                             tiled=True),
                    "down": jax.lax.all_gather(we["down"], fsdp_axes, axis=1,
                                               tiled=True),
                }
            y, aux = _moe_local(x_loc.reshape(b * s, d), router_w, we, cfg,
                                ep_axis)
            # aux already pmean'd over EP; mean over the token axes too
            other = tuple(a for a in mesh.axis_names if a != ep_axis)
            if other:
                aux = jax.lax.pmean(aux, other)
            return y.reshape(b, s, d), aux

        egate = sh.spec("experts", "fsdp", None)
        edown = sh.spec("experts", "fsdp", None)   # down: (E, fm, d) — shard fm
        # check_vma=False: when tokens are not model-sharded (decode, S=1)
        # every model shard computes identical outputs from identical inputs —
        # replication holds by construction but cannot be statically inferred
        # through the all_to_all (verified numerically in tests/test_system).
        y, aux = jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(token_spec, P(None, None),
                      {"gate": egate, "up": egate, "down": edown}),
            out_specs=(token_spec, P()),
            check_vma=False,
        )(x, p["router"], p["experts"])

    if cfg.n_shared_experts:
        dt = x.dtype
        sp = p["shared"]
        h = jax.nn.silu(x @ sp["gate"].astype(dt)) * (x @ sp["up"].astype(dt))
        h = sh.constrain(h, "batch", "seq", "ff")
        y = y + h @ sp["down"].astype(dt)
    return sh.constrain(y, "batch", "seq", "embed"), aux
