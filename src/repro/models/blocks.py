"""Block-level composition: one function + param-init + axes per block kind.

Kinds:
  dense      pre-norm GQA attention + gated MLP           (qwen/internlm/glm/phi3v)
  mla_dense  MLA attention + gated MLP                    (deepseek dense prefix)
  moe        GQA attention + MoE FFN (+ shared experts)   (kimi)
  mla_moe    MLA attention + MoE FFN                      (deepseek)
  rec        RG-LRU recurrent block + GeGLU MLP           (recurrentgemma)
  lattn      local (sliding-window) MQA attention + MLP   (recurrentgemma)
  mamba      Mamba-2 SSD mixer                            (mamba2)
  enc        bidirectional attention + GELU MLP (LN+bias) (whisper encoder)
  dec        causal self-attn + cross-attn + GELU MLP     (whisper decoder)

Every block returns ``(x, cache, aux)`` with aux = MoE load-balance loss (0.0
elsewhere) so stacks can be scanned uniformly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import sharding as sh
from .griffin import RecurrentCache, rec_axes, rec_params_init, recurrent_block
from .layers import (
    KVCache,
    attention,
    attn_axes,
    attn_params_init,
    dense_init,
    layernorm,
    mlp,
    mlp_axes,
    mlp_params_init,
    moe_axes,
    moe_ffn,
    moe_params_init,
    rmsnorm,
)
from .mamba2 import SSMCache, mamba_axes, mamba_block, mamba_params_init
from .mla import MLACache, mla_attention, mla_axes, mla_params_init


def _norm_init(cfg, with_bias=False):
    dt = jnp.dtype(cfg.param_dtype)
    if with_bias:
        return {"w": jnp.ones((cfg.d_model,), dt), "b": jnp.zeros((cfg.d_model,), dt)}
    return {"w": jnp.ones((cfg.d_model,), dt)}


def _norm(x, p, cfg):
    if "b" in p:
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


_NORM_AXES = {"w": ("embed",)}
_NORM_AXES_B = {"w": ("embed",), "b": ("embed",)}


# ---------------------------------------------------------------------------


def init_block(kind: str, key, cfg):
    ks = jax.random.split(key, 4)
    if kind in ("dense", "lattn"):
        return {"ln1": _norm_init(cfg), "attn": attn_params_init(ks[0], cfg),
                "ln2": _norm_init(cfg), "mlp": mlp_params_init(ks[1], cfg)}
    if kind == "mla_dense":
        return {"ln1": _norm_init(cfg), "attn": mla_params_init(ks[0], cfg),
                "ln2": _norm_init(cfg), "mlp": mlp_params_init(ks[1], cfg)}
    if kind == "moe":
        return {"ln1": _norm_init(cfg), "attn": attn_params_init(ks[0], cfg),
                "ln2": _norm_init(cfg), "moe": moe_params_init(ks[1], cfg)}
    if kind == "mla_moe":
        return {"ln1": _norm_init(cfg), "attn": mla_params_init(ks[0], cfg),
                "ln2": _norm_init(cfg), "moe": moe_params_init(ks[1], cfg)}
    if kind == "rec":
        return {"ln1": _norm_init(cfg), "rec": rec_params_init(ks[0], cfg),
                "ln2": _norm_init(cfg), "mlp": mlp_params_init(ks[1], cfg)}
    if kind == "mamba":
        return {"ln": _norm_init(cfg), "mixer": mamba_params_init(ks[0], cfg)}
    if kind == "enc":
        return {"ln1": _norm_init(cfg, True), "attn": attn_params_init(ks[0], cfg),
                "ln2": _norm_init(cfg, True), "mlp": mlp_params_init(ks[1], cfg)}
    if kind == "dec":
        return {"ln1": _norm_init(cfg, True), "attn": attn_params_init(ks[0], cfg),
                "lnx": _norm_init(cfg, True), "xattn": attn_params_init(ks[1], cfg),
                "ln2": _norm_init(cfg, True), "mlp": mlp_params_init(ks[2], cfg)}
    raise KeyError(kind)


def block_axes(kind: str, cfg):
    if kind in ("dense", "lattn"):
        return {"ln1": _NORM_AXES, "attn": attn_axes(cfg),
                "ln2": _NORM_AXES, "mlp": mlp_axes(cfg)}
    if kind == "mla_dense":
        return {"ln1": _NORM_AXES, "attn": mla_axes(cfg),
                "ln2": _NORM_AXES, "mlp": mlp_axes(cfg)}
    if kind == "moe":
        return {"ln1": _NORM_AXES, "attn": attn_axes(cfg),
                "ln2": _NORM_AXES, "moe": moe_axes(cfg)}
    if kind == "mla_moe":
        return {"ln1": _NORM_AXES, "attn": mla_axes(cfg),
                "ln2": _NORM_AXES, "moe": moe_axes(cfg)}
    if kind == "rec":
        return {"ln1": _NORM_AXES, "rec": rec_axes(cfg),
                "ln2": _NORM_AXES, "mlp": mlp_axes(cfg)}
    if kind == "mamba":
        return {"ln": _NORM_AXES, "mixer": mamba_axes(cfg)}
    if kind == "enc":
        return {"ln1": _NORM_AXES_B, "attn": attn_axes(cfg),
                "ln2": _NORM_AXES_B, "mlp": mlp_axes(cfg)}
    if kind == "dec":
        return {"ln1": _NORM_AXES_B, "attn": attn_axes(cfg),
                "lnx": _NORM_AXES_B, "xattn": attn_axes(cfg),
                "ln2": _NORM_AXES_B, "mlp": mlp_axes(cfg)}
    raise KeyError(kind)


def init_cache(kind: str, cfg, batch: int, s_max: int, enc_seq: int = 0):
    """Empty serving cache for one block of this kind."""
    from .mamba2 import _dims

    cdt = jnp.dtype(cfg.dtype)
    if kind in ("dense", "moe", "enc"):
        KV, hd = cfg.n_kv_heads, cfg.head_dim
        return KVCache(k=jnp.zeros((batch, s_max, KV, hd), cdt),
                       v=jnp.zeros((batch, s_max, KV, hd), cdt),
                       length=jnp.zeros((batch,), jnp.int32))
    if kind == "lattn":
        KV, hd = cfg.n_kv_heads, cfg.head_dim
        s = min(s_max, cfg.window) if cfg.window else s_max
        # ring-less window cache: we keep full s_max for index simplicity at
        # dry-run scale; the window mask bounds the attention cost.
        return KVCache(k=jnp.zeros((batch, s_max, KV, hd), cdt),
                       v=jnp.zeros((batch, s_max, KV, hd), cdt),
                       length=jnp.zeros((batch,), jnp.int32))
    if kind in ("mla_dense", "mla_moe"):
        return MLACache(
            ckv=jnp.zeros((batch, s_max, cfg.kv_lora_rank), cdt),
            krope=jnp.zeros((batch, s_max, cfg.qk_rope_dim), cdt),
            length=jnp.zeros((batch,), jnp.int32))
    if kind == "rec":
        return RecurrentCache(
            conv=jnp.zeros((batch, cfg.conv_kernel - 1, cfg.lru_width), cdt),
            h=jnp.zeros((batch, cfg.lru_width), jnp.float32))
    if kind == "mamba":
        d_in, H, G, N, P, conv_ch = _dims(cfg)
        return SSMCache(
            conv=jnp.zeros((batch, cfg.conv_kernel - 1, conv_ch), cdt),
            h=jnp.zeros((batch, H, P, N), jnp.float32))
    if kind == "dec":
        KV, hd = cfg.n_kv_heads, cfg.head_dim
        return {
            "self": KVCache(k=jnp.zeros((batch, s_max, KV, hd), cdt),
                            v=jnp.zeros((batch, s_max, KV, hd), cdt),
                            length=jnp.zeros((batch,), jnp.int32)),
            "cross_k": jnp.zeros((batch, enc_seq, KV, hd), cdt),
            "cross_v": jnp.zeros((batch, enc_seq, KV, hd), cdt),
        }
    raise KeyError(kind)


def cache_axes(kind: str, cfg):
    """Logical sharding axes mirroring :func:`init_cache`'s structure."""
    kv = ("batch", "kv_seq", "kv_heads", None)
    if kind in ("dense", "moe", "enc", "lattn"):
        return KVCache(k=kv, v=kv, length=("batch",))
    if kind in ("mla_dense", "mla_moe"):
        return MLACache(ckv=("batch", "kv_seq", None),
                        krope=("batch", "kv_seq", None), length=("batch",))
    if kind == "rec":
        return RecurrentCache(conv=("batch", None, "lru"), h=("batch", "lru"))
    if kind == "mamba":
        return SSMCache(conv=("batch", None, "ff"),
                        h=("batch", "ssm_heads", None, None))
    if kind == "dec":
        return {"self": KVCache(k=kv, v=kv, length=("batch",)),
                "cross_k": ("batch", None, "kv_heads", None),
                "cross_v": ("batch", None, "kv_heads", None)}
    raise KeyError(kind)


def _cross_attention(x, p, cfg, ck, cv):
    """Decoder cross-attention against precomputed encoder K/V."""
    import math

    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    q = q.reshape(B, S, H, hd)
    from .layers import _sdpa

    mask = jnp.ones((B, S, ck.shape[1]), bool)
    y = _sdpa(q, ck, cv, mask)
    y = y.reshape(B, S, H * hd) @ p["wo"].astype(dt)
    return y


def cross_kv(x_enc, p, cfg):
    """Precompute encoder K/V for a decoder block's cross-attention."""
    B, S, D = x_enc.shape
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    dt = x_enc.dtype
    k = x_enc @ p["wk"].astype(dt)
    v = x_enc @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k.reshape(B, S, KV, hd), v.reshape(B, S, KV, hd)


def apply_block(kind: str, x, p, cfg, positions, cache=None):
    """Returns (x_out, new_cache, aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe"):
        h, c = attention(_norm(x, p["ln1"], cfg), p["attn"], cfg, positions,
                         cache=cache)
        x = x + h
        if kind == "dense":
            x = x + mlp(_norm(x, p["ln2"], cfg), p["mlp"], cfg)
            return x, c, zero
        y, aux = moe_ffn(_norm(x, p["ln2"], cfg), p["moe"], cfg)
        return x + y, c, aux
    if kind in ("mla_dense", "mla_moe"):
        h, c = mla_attention(_norm(x, p["ln1"], cfg), p["attn"], cfg, positions,
                             cache=cache)
        x = x + h
        if kind == "mla_dense":
            x = x + mlp(_norm(x, p["ln2"], cfg), p["mlp"], cfg)
            return x, c, zero
        y, aux = moe_ffn(_norm(x, p["ln2"], cfg), p["moe"], cfg)
        return x + y, c, aux
    if kind == "lattn":
        h, c = attention(_norm(x, p["ln1"], cfg), p["attn"], cfg, positions,
                         window=cfg.window, cache=cache)
        x = x + h
        x = x + mlp(_norm(x, p["ln2"], cfg), p["mlp"], cfg)
        return x, c, zero
    if kind == "rec":
        h, c = recurrent_block(_norm(x, p["ln1"], cfg), p["rec"], cfg,
                               cache=cache)
        x = x + h
        x = x + mlp(_norm(x, p["ln2"], cfg), p["mlp"], cfg)
        return x, c, zero
    if kind == "mamba":
        h, c = mamba_block(_norm(x, p["ln"], cfg), p["mixer"], cfg, cache=cache)
        return x + h, c, zero
    if kind == "enc":
        # bidirectional self-attention (no mask, no rope — whisper uses
        # absolute sinusoidal positions added at the embedding)
        from .layers import _qkv, _sdpa

        B, S, _ = x.shape
        xn = _norm(x, p["ln1"], cfg)
        q, k, v = _qkv(xn, p["attn"], cfg)
        y = _sdpa(q, k, v, jnp.ones((B, S, S), bool))
        y = y.reshape(B, S, -1) @ p["attn"]["wo"].astype(x.dtype)
        x = x + y
        x = x + mlp(_norm(x, p["ln2"], cfg), p["mlp"], cfg)
        return x, None, zero
    if kind == "dec":
        sc = cache.get("self") if cache is not None else None
        h, new_self = attention(_norm(x, p["ln1"], cfg), p["attn"], cfg,
                                positions, cache=sc)
        x = x + h
        ck, cv = cache["cross_k"], cache["cross_v"]
        x = x + _cross_attention(_norm(x, p["lnx"], cfg), p["xattn"], cfg, ck, cv)
        x = x + mlp(_norm(x, p["ln2"], cfg), p["mlp"], cfg)
        new_cache = {"self": new_self, "cross_k": ck, "cross_v": cv}
        return x, new_cache, zero
    raise KeyError(kind)
