"""Model factory: builds every assigned architecture from its ModelConfig.

One uniform interface for the launcher, trainer, server and dry-run:

    m = build_model(cfg)
    params = m.init(key)                        # concrete (smoke/train)
    specs  = jax.eval_shape(m.init, key)        # dry-run param ShapeDtypeStructs
    loss, aux = m.loss(params, batch)
    logits, caches = m.prefill(params, batch)
    caches = m.init_caches(B, S_max, filled=S)  # serving state
    logits, caches = m.decode_step(params, tokens, caches, pos)

Layer stacks are grouped into homogeneous runs; each run of length >1 is
``lax.scan``-ned when ``cfg.scan_layers`` (compile time stays flat in depth)
with optional ``jax.checkpoint`` rematerialisation.  Heterogeneous patterns
(RecurrentGemma's rec-rec-attn) scan over the repeating *period*.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import sharding as sh
from .blocks import (apply_block, block_axes, cache_axes as _block_cache_axes,
                     cross_kv, init_block, init_cache)
from .layers import dense_init, layernorm, rmsnorm

Pytree = Any


def layer_groups(cfg: ModelConfig) -> list[tuple[tuple[str, ...], int]]:
    """List of (period_kinds, repeat).  A period of one kind is the common
    case; RecurrentGemma uses the repeating period ("rec","rec","lattn")."""
    if cfg.family == "ssm":
        return [(("mamba",), cfg.n_layers)]
    if cfg.family == "hybrid":
        pat = tuple("lattn" if k == "attn" else k for k in cfg.block_pattern)
        reps = cfg.n_layers // len(pat)
        out: list[tuple[tuple[str, ...], int]] = [(pat, reps)]
        tail = cfg.n_layers - reps * len(pat)
        if tail:
            out.append((pat[:tail], 1))
        return out
    if cfg.family == "moe":
        attn = "mla_dense" if cfg.use_mla else "dense"
        moe = "mla_moe" if cfg.use_mla else "moe"
        out = []
        if cfg.n_dense_layers:
            out.append(((attn,), cfg.n_dense_layers))
        out.append(((moe,), cfg.n_layers - cfg.n_dense_layers))
        return out
    if cfg.family == "audio":
        return [(("dec",), cfg.n_layers)]          # encoder handled separately
    return [(("dense",), cfg.n_layers)]            # dense / vlm


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    axes: Callable
    loss: Callable          # (params, batch) -> (loss, aux)
    prefill: Callable       # (params, batch) -> (last logits, caches)
    decode_step: Callable   # (params, tokens(B,1), caches, pos(B,)) -> (logits, caches)
    init_caches: Callable   # (batch, s_max, filled=0) -> caches
    cache_axes: Callable    # () -> logical axes tree mirroring init_caches


def _sinusoidal(positions, d):
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (math.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _stack_pytrees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def build_model(cfg: ModelConfig) -> Model:
    groups = layer_groups(cfg)
    cdt = jnp.dtype(cfg.dtype)
    pdt = jnp.dtype(cfg.param_dtype)

    # ---------------------------------------------------------------- init --

    def init(key: jax.Array) -> Pytree:
        n_groups = len(groups)
        keys = jax.random.split(key, n_groups + 5)
        p: dict[str, Any] = {}
        p["embed"] = dense_init(keys[0], (cfg.vocab_size, cfg.d_model), pdt,
                                scale=0.02)
        p["final_norm"] = {"w": jnp.ones((cfg.d_model,), pdt)}
        if not cfg.tie_embeddings:
            p["head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size), pdt)
        stacks = []
        for g, (period, reps) in enumerate(groups):
            def one(k, _period=period):
                ks = jax.random.split(k, len(_period))
                return {f"b{i}": init_block(kind, ks[i], cfg)
                        for i, kind in enumerate(_period)}
            if reps == 1:
                stacks.append(one(keys[2 + g]))
            else:
                stacks.append(jax.vmap(one)(jax.random.split(keys[2 + g], reps)))
        p["stacks"] = stacks
        if cfg.family == "audio":
            ek = jax.random.split(keys[n_groups + 2], cfg.enc_layers)
            p["enc"] = jax.vmap(lambda k: init_block("enc", k, cfg))(ek)
            p["enc_norm"] = {"w": jnp.ones((cfg.d_model,), pdt),
                             "b": jnp.zeros((cfg.d_model,), pdt)}
        if cfg.mtp:
            kk = jax.random.split(keys[n_groups + 3], 2)
            p["mtp"] = {
                "proj": dense_init(kk[0], (2 * cfg.d_model, cfg.d_model), pdt),
                "block": init_block("mla_dense" if cfg.use_mla else "dense",
                                    kk[1], cfg),
                "norm": {"w": jnp.ones((cfg.d_model,), pdt)},
            }
        return p

    # ---------------------------------------------------------------- axes --

    def axes() -> Pytree:
        def _is_axes(x):
            return isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x)

        a: dict[str, Any] = {
            "embed": ("vocab", "fsdp"),
            "final_norm": {"w": ("embed",)},
        }
        if not cfg.tie_embeddings:
            a["head"] = ("fsdp", "vocab")
        stacks = []
        for period, reps in groups:
            blk = {f"b{i}": block_axes(kind, cfg)
                   for i, kind in enumerate(period)}
            if reps > 1:
                blk = jax.tree.map(lambda t: ("layers",) + t, blk,
                                   is_leaf=_is_axes)
            stacks.append(blk)
        a["stacks"] = stacks
        if cfg.family == "audio":
            enc = jax.tree.map(lambda t: ("layers",) + t, block_axes("enc", cfg),
                               is_leaf=_is_axes)
            a["enc"] = enc
            a["enc_norm"] = {"w": ("embed",), "b": ("embed",)}
        if cfg.mtp:
            a["mtp"] = {
                "proj": ("fsdp", None),
                "block": block_axes("mla_dense" if cfg.use_mla else "dense", cfg),
                "norm": {"w": ("embed",)},
            }
        return a

    # --------------------------------------------------------------- stacks --

    def _remat(fn):
        if cfg.remat == "none":
            return fn
        policy = None
        if cfg.remat == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)

    def run_stacks(x, stacks, positions, caches):
        """caches: list matching groups; entries may be None (no state needed,
        e.g. training without serving caches is handled by passing cross-KV
        only for audio).  Returns (x, new_caches, aux_total)."""
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []
        for g, (period, reps) in enumerate(groups):
            sp = stacks[g]
            gc = caches[g] if caches is not None else None

            def period_fn(x, pp, pc, _period=period):
                aux = jnp.zeros((), jnp.float32)
                ncs = {}
                for i, kind in enumerate(_period):
                    c = pc[f"b{i}"] if pc is not None else None
                    x, nc, a_ = apply_block(kind, x, pp[f"b{i}"], cfg,
                                            positions, cache=c)
                    ncs[f"b{i}"] = nc
                    aux = aux + a_
                return x, ncs, aux

            period_fn = _remat(period_fn)

            if reps == 1:
                x, ncs, a_ = period_fn(x, sp, gc)
                new_caches.append(ncs)
                aux_total = aux_total + a_
            elif cfg.scan_layers:
                if gc is None:
                    def body(carry, pp):
                        x, aux = carry
                        x, ncs, a_ = period_fn(x, pp, None)
                        return (x, aux + a_), ncs
                    (x, aux_total), ncs = jax.lax.scan(body, (x, aux_total), sp)
                else:
                    def body(carry, xs):
                        x, aux = carry
                        pp, pc = xs
                        x, ncs, a_ = period_fn(x, pp, pc)
                        return (x, aux + a_), ncs
                    (x, aux_total), ncs = jax.lax.scan(
                        body, (x, aux_total), (sp, gc))
                new_caches.append(ncs)
            else:
                ncs_list = []
                for r in range(reps):
                    pp = jax.tree.map(lambda t: t[r], sp)
                    pc = (jax.tree.map(lambda t: t[r], gc)
                          if gc is not None else None)
                    x, ncs, a_ = period_fn(x, pp, pc)
                    ncs_list.append(ncs)
                    aux_total = aux_total + a_
                new_caches.append(_stack_pytrees(ncs_list))
        return x, new_caches, aux_total

    # ----------------------------------------------------- embedding / head --

    def embed_tokens(p, tokens, positions=None):
        x = jnp.take(p["embed"], tokens, axis=0).astype(cdt)
        if not cfg.rope_theta:           # absolute sinusoidal positions
            if positions is None:
                positions = jnp.arange(tokens.shape[1])[None, :]
            x = x + _sinusoidal(positions, cfg.d_model).astype(cdt)
        return sh.constrain(x, "batch", "seq", "embed")

    def lm_logits(p, x):
        x = rmsnorm(x, p["final_norm"]["w"], cfg.norm_eps)
        head = p["embed"].T if cfg.tie_embeddings else p["head"]
        logits = x @ head.astype(cdt)
        return sh.constrain(logits, "batch", "seq", "vocab")

    def xent(logits, targets, mask=None):
        # manual logsumexp keeping the exp in the compute dtype: avoids
        # materialising an f32 copy of the (B,S,V) logits
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        z = jnp.sum(jnp.exp(logits - m), axis=-1, dtype=jnp.float32)
        logz = jnp.log(z) + m[..., 0].astype(jnp.float32)
        gold = jnp.take_along_axis(logits, targets[..., None],
                                   axis=-1)[..., 0].astype(jnp.float32)
        nll = logz - gold
        if mask is not None:
            return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return nll.mean()

    def encode_frames(p, frames):
        x = frames.astype(cdt)
        pos = jnp.arange(x.shape[1])[None, :]
        x = x + _sinusoidal(pos, cfg.d_model).astype(cdt)
        x = sh.constrain(x, "batch", "seq", "embed")

        def body(carry, pp):
            x = carry
            x, _, _ = apply_block("enc", x, pp, cfg, pos)
            return x, None

        x, _ = jax.lax.scan(body, x, p["enc"])
        return layernorm(x, p["enc_norm"]["w"], p["enc_norm"]["b"], cfg.norm_eps)

    def audio_cross_caches(p, enc_out):
        """Cross-attention K/V per decoder layer (train: the only cache)."""
        def per_layer(pp):
            ck, cv = cross_kv(enc_out, pp["b0"]["xattn"], cfg)
            return {"b0": {"cross_k": ck, "cross_v": cv}}
        return [jax.vmap(per_layer)(p["stacks"][0])]

    # --------------------------------------------------------------- inputs --

    def build_inputs(p, batch):
        """Returns (x, positions, targets, loss_mask, caches_for_train)."""
        if cfg.family == "vlm":
            patches = batch["patches"].astype(cdt)       # (B, Np, D)
            tokens = batch["tokens"]                     # (B, St+1)
            inp, tgt = tokens[:, :-1], tokens[:, 1:]
            xt = jnp.take(p["embed"], inp, axis=0).astype(cdt)
            x = jnp.concatenate([patches, xt], axis=1)
            B, S, _ = x.shape
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
            Np = patches.shape[1]
            targets = jnp.concatenate(
                [jnp.zeros((B, Np), tgt.dtype), tgt], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros((B, Np), jnp.float32),
                 jnp.ones_like(tgt, jnp.float32)], axis=1)
            return (sh.constrain(x, "batch", "seq", "embed"), positions,
                    targets, mask, None)
        if cfg.family == "audio":
            tokens = batch["tokens"]
            inp, tgt = tokens[:, :-1], tokens[:, 1:]
            x = embed_tokens(p, inp)
            B, S, _ = x.shape
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
            enc_out = encode_frames(p, batch["frames"])
            return x, positions, tgt, None, audio_cross_caches(p, enc_out)
        tokens = batch["tokens"]
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        x = embed_tokens(p, inp)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        return x, positions, tgt, None, None

    # ----------------------------------------------------------------- loss --

    def loss(p, batch):
        x, positions, targets, mask, train_caches = build_inputs(p, batch)
        x, _, aux = run_stacks(x, p["stacks"], positions, train_caches)
        logits = lm_logits(p, x)
        total = xent(logits, targets, mask) + cfg.router_aux_weight * aux
        if cfg.mtp:
            tokens = batch["tokens"]
            h = x[:, :-1]
            nxt = embed_tokens(p, tokens[:, 1:-1])
            m = p["mtp"]
            z = jnp.concatenate(
                [rmsnorm(h, m["norm"]["w"], cfg.norm_eps), nxt], axis=-1)
            z = z @ m["proj"].astype(cdt)
            B, S2, _ = z.shape
            pos2 = jnp.broadcast_to(jnp.arange(S2)[None, :], (B, S2))
            kind = "mla_dense" if cfg.use_mla else "dense"
            z, _, _ = apply_block(kind, z, m["block"], cfg, pos2)
            total = total + 0.3 * xent(lm_logits(p, z), tokens[:, 2:])
        return total, aux

    # -------------------------------------------------------------- serving --

    def init_caches(batch: int, s_max: int, filled: int = 0) -> Pytree:
        out = []
        for period, reps in groups:
            def one():
                c = {f"b{i}": init_cache(kind, cfg, batch, s_max,
                                         enc_seq=cfg.enc_seq)
                     for i, kind in enumerate(period)}
                if filled:
                    c = jax.tree.map(
                        lambda t: (jnp.full_like(t, filled)
                                   if t.dtype == jnp.int32 and t.ndim == 1
                                   else t), c)
                return c
            c = one()
            if reps > 1:
                c = jax.tree.map(
                    lambda t: jnp.broadcast_to(t[None], (reps,) + t.shape), c)
            out.append(c)
        return out

    def cache_axes() -> Pytree:
        """Logical sharding axes mirroring init_caches' structure."""
        def _is_axes(x):
            return isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x)
        out = []
        for period, reps in groups:
            c = {f"b{i}": _block_cache_axes(kind, cfg)
                 for i, kind in enumerate(period)}
            if reps > 1:
                c = jax.tree.map(lambda t: ("layers",) + t, c, is_leaf=_is_axes)
            out.append(c)
        return out

    def prefill(p, batch):
        if cfg.family == "audio":
            tokens = batch["tokens"]
            enc_out = encode_frames(p, batch["frames"])
            x = embed_tokens(p, tokens)
            caches = audio_cross_caches(p, enc_out)
        elif cfg.family == "vlm":
            patches = batch["patches"].astype(cdt)
            tokens = batch["tokens"]
            xt = jnp.take(p["embed"], tokens, axis=0).astype(cdt)
            x = jnp.concatenate([patches, xt], axis=1)
            caches = None
        else:
            tokens = batch["tokens"]
            x = embed_tokens(p, tokens)
            caches = None
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x = sh.constrain(x, "batch", "seq", "embed")
        x, new_caches, _ = run_stacks(x, p["stacks"], positions, caches)
        return lm_logits(p, x[:, -1:]), new_caches

    def decode_step(p, tokens, caches, pos):
        """tokens: (B,1); pos: (B,) current position (== tokens generated)."""
        B = tokens.shape[0]
        x = jnp.take(p["embed"], tokens, axis=0).astype(cdt)
        if not cfg.rope_theta:
            x = x + _sinusoidal(pos[:, None], cfg.d_model).astype(cdt)
        x = sh.constrain(x, "batch", "seq", "embed")
        positions = pos[:, None]
        x, new_caches, _ = run_stacks(x, p["stacks"], positions, caches)
        return lm_logits(p, x), new_caches

    return Model(cfg=cfg, init=init, axes=axes, loss=loss, prefill=prefill,
                 decode_step=decode_step, init_caches=init_caches,
                 cache_axes=cache_axes)


# ---------------------------------------------------------------------------
# parameter counting (MODEL_FLOPS for the roofline tables)
# ---------------------------------------------------------------------------


def count_params_from_specs(cfg: ModelConfig, active_only: bool = False) -> int:
    m = build_model(cfg)
    specs = jax.eval_shape(lambda: m.init(jax.random.key(0)))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
        n = 1
        for s in leaf.shape:
            n *= int(s)
        if active_only and cfg.n_experts and any(
            getattr(k, "key", None) == "experts" for k in path
        ):
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return total
