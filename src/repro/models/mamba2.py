"""Mamba-2 mixer (SSD — state-space duality, arXiv:2405.21060).

Train/prefill use the chunked SSD algorithm (batched version of
``kernels/ref.ssd_ref_chunked``; the Pallas kernel in ``kernels/ssd.py``
implements the same schedule for TPU).  The chunk length ``cfg.ssd_chunk`` is
a tile size in the paper's search space.  Decode is the O(1) recurrence on the
(H, P, N) state.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import sharding as sh
from .layers import dense_init, rmsnorm


class SSMCache(NamedTuple):
    conv: jax.Array          # (B, K-1, conv_channels)
    h: jax.Array             # (B, H, P, N) ssm state (f32)


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_headdim
    G, N, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    conv_ch = d_in + 2 * G * N
    return d_in, H, G, N, P, conv_ch


def mamba_params_init(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    d_in, H, G, N, P, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * G * N + H), dt),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, conv_ch), dt, scale=0.3),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)).astype(dt),
        "d_skip": jnp.ones((H,), dt),
        "dt_bias": jnp.zeros((H,), dt),
        "norm_w": jnp.ones((d_in,), dt),
        "out_proj": dense_init(ks[2], (d_in, d), dt),
    }


def mamba_axes(cfg):
    return {"in_proj": ("fsdp", "ff"), "conv_w": (None, "ff"),
            "conv_b": ("ff",), "a_log": ("ssm_heads",), "d_skip": ("ssm_heads",),
            "dt_bias": ("ssm_heads",), "norm_w": ("ff",),
            "out_proj": ("ff", "fsdp")}


def _ssd_chunked(x, dtv, a, b, c, chunk):
    """Batched chunked SSD.  x: (B,L,H,P); dtv: (B,L,H); a: (H,);
    b,c: (B,L,G,N) head-grouped.  Returns y (B,L,H,P), final state (B,H,P,N)."""
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    hpg = H // G
    ch = min(chunk, L)
    assert L % ch == 0
    nc = L // ch

    xf = x.astype(jnp.float32).reshape(B, nc, ch, H, P)
    dtf = dtv.astype(jnp.float32).reshape(B, nc, ch, H)
    bf = jnp.repeat(b.astype(jnp.float32), hpg, axis=2).reshape(B, nc, ch, H, N)
    cf = jnp.repeat(c.astype(jnp.float32), hpg, axis=2).reshape(B, nc, ch, H, N)

    la = dtf * a[None, None, None, :]                 # log decay
    cum = jnp.cumsum(la, axis=2)                      # (B,nc,ch,H) inclusive
    mask = jnp.tril(jnp.ones((ch, ch), bool))
    # mask BEFORE exp: the strictly-upper entries have positive exponents that
    # overflow (and poison gradients through the jnp.where)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    seg = jnp.where(mask[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)
    scores = jnp.einsum("bnthk,bnshk->bntsh", cf, bf) * decay
    y_intra = jnp.einsum("bntsh,bnsh,bnshp->bnthp", scores, dtf, xf)

    # chunk summaries: state contribution of each chunk and its total decay
    total = cum[:, :, -1, :]                          # (B,nc,H)
    w = jnp.exp(total[:, :, None, :] - cum) * dtf     # (B,nc,ch,H)
    chunk_state = jnp.einsum("bnsh,bnshk,bnshp->bnhpk", w, bf, xf)

    # scan over chunks: h_{n} = exp(total_n)·h_{n-1} + chunk_state_n
    def step(h, inp):
        tot, cs = inp
        h = jnp.exp(tot)[..., None, None] * h + cs
        return h, h

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    hT, h_after = jax.lax.scan(
        step, h0, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(chunk_state, 1, 0)))
    h_before = jnp.concatenate([h0[None], h_after[:-1]], axis=0)  # state entering chunk n
    h_before = jnp.moveaxis(h_before, 0, 1)                       # (B,nc,H,P,N)

    y_inter = jnp.einsum("bnthk,bnhpk,bnth->bnthp", cf, h_before, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, L, H, P)
    return y, hT


def mamba_block(x, p, cfg, *, cache: SSMCache | None = None):
    """Returns (y, new_cache).  x: (B,S,D)."""
    from .griffin import _causal_conv

    B, S, D = x.shape
    d_in, H, G, N, P, conv_ch = _dims(cfg)
    dt_ = x.dtype

    zxbcdt = x @ p["in_proj"].astype(dt_)             # (B,S,2*d_in+2GN+H)
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * G * N], axis=-1)
    xbc = sh.constrain(xbc, "batch", "seq", None)

    conv_state = cache.conv if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)

    xs, b, c = jnp.split(xbc, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    b = b.reshape(B, S, G, N)
    c = c.reshape(B, S, G, N)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))     # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                  # (H,) negative

    if cache is None:
        y, hT = _ssd_chunked(xs, dtv, a, b, c, cfg.ssd_chunk)
    else:
        hpg = H // G
        bg = jnp.repeat(b[:, 0], hpg, axis=1)         # (B,H,N)
        cg = jnp.repeat(c[:, 0], hpg, axis=1)
        decay = jnp.exp(dtv[:, 0] * a[None, :])       # (B,H)
        upd = (dtv[:, 0, :, None] * xs[:, 0].astype(jnp.float32))[..., None] \
            * bg[:, :, None, :].astype(jnp.float32)   # (B,H,P,N)
        hT = decay[..., None, None] * cache.h + upd
        y = jnp.einsum("bhpn,bhn->bhp", hT, cg.astype(jnp.float32))[:, None]

    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_in).astype(dt_)
    # gated RMSNorm (mamba2's norm before out_proj)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_)
    out = sh.constrain(out, "batch", "seq", "embed")
    new_cache = SSMCache(conv=new_conv.astype(x.dtype), h=hT)
    return out, new_cache
