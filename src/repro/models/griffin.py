"""RecurrentGemma / Griffin blocks (arXiv:2402.19427).

Recurrent block: dual-branch (gate ⊙ RG-LRU(conv1d(x-branch))), where the
RG-LRU is a gated diagonal linear recurrence
    a_t = exp(-c·softplus(Λ)·r_t),   h_t = a_t h_{t-1} + √(1−a_t²)·(i_t ⊙ x_t)
computed with an associative scan for train/prefill and a single-step update
for decode.  Attention blocks are local (sliding-window 2048) MQA — handled by
``layers.attention(window=...)``.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import sharding as sh
from .layers import dense_init

_C = 8.0          # Griffin's fixed gate sharpness


class RecurrentCache(NamedTuple):
    conv: jax.Array          # (B, K-1, W) trailing conv inputs
    h: jax.Array             # (B, W) RG-LRU hidden state


def rec_params_init(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7)
    # Λ init so that a ∈ [0.9, 0.999] at r=1 (paper appendix)
    lam = jnp.log(jnp.expm1(-jnp.log(
        jnp.linspace(0.9, 0.999, w).astype(jnp.float32)) / _C))
    return {
        "in_x": dense_init(ks[0], (d, w), dt),
        "in_gate": dense_init(ks[1], (d, w), dt),
        "conv_w": dense_init(ks[2], (cfg.conv_kernel, w), dt, scale=0.3),
        "conv_b": jnp.zeros((w,), dt),
        "wr": dense_init(ks[3], (w, w), dt),
        "br": jnp.zeros((w,), dt),
        "wi": dense_init(ks[4], (w, w), dt),
        "bi": jnp.zeros((w,), dt),
        "lam": lam.astype(dt),
        "out": dense_init(ks[5], (w, d), dt),
    }


def rec_axes(cfg):
    return {"in_x": ("fsdp", "lru"), "in_gate": ("fsdp", "lru"),
            "conv_w": (None, "lru"), "conv_b": ("lru",),
            "wr": ("fsdp", "lru"), "br": ("lru",),
            "wi": ("fsdp", "lru"), "bi": ("lru",),
            "lam": ("lru",), "out": ("lru", "fsdp")}


def _causal_conv(x, w, b, state=None):
    """x: (B,S,W); w: (K,W) depthwise.  state: (B,K-1,W) trailing context."""
    B, S, W = x.shape
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((B, K - 1, W), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+K-1, W)
    y = sum(xp[:, i : i + S, :] * w[i].astype(x.dtype) for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else jnp.zeros((B, 0, W), x.dtype)
    return y + b.astype(x.dtype), new_state


def _rg_lru_scan(x, r, i, lam):
    """Associative linear recurrence h_t = a_t·h_{t-1} + b_t over axis 1."""
    log_a = -_C * jax.nn.softplus(lam.astype(jnp.float32)) * r    # (B,S,W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * x.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_seq, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h, a, gated


def recurrent_block(x, p, cfg, *, cache: RecurrentCache | None = None):
    """Returns (y, new_cache).  x: (B,S,D)."""
    B, S, D = x.shape
    dt = x.dtype
    gate = jax.nn.gelu(x @ p["in_gate"].astype(dt))
    xb = x @ p["in_x"].astype(dt)
    xb = sh.constrain(xb, "batch", "seq", "lru")

    conv_state = cache.conv if cache is not None else None
    xb, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)

    r = jax.nn.sigmoid((xb @ p["wr"].astype(dt) + p["br"].astype(dt))
                       .astype(jnp.float32))
    i = jax.nn.sigmoid((xb @ p["wi"].astype(dt) + p["bi"].astype(dt))
                       .astype(jnp.float32))

    if cache is None:
        h, _, _ = _rg_lru_scan(xb, r, i, p["lam"])
        new_h = h[:, -1, :]
    else:
        log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r[:, 0]
        a = jnp.exp(log_a)
        b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
            i[:, 0] * xb[:, 0].astype(jnp.float32))
        new_h = a * cache.h.astype(jnp.float32) + b
        h = new_h[:, None, :]

    y = (h.astype(dt) * gate) @ p["out"].astype(dt)
    y = sh.constrain(y, "batch", "seq", "embed")
    new_cache = RecurrentCache(conv=new_conv.astype(x.dtype),
                               h=new_h.astype(jnp.float32))
    return y, new_cache
