"""Logical-axis sharding rules (MaxText-style), tunable by the distributed
configuration search (repro.core.distconfig).

Model code annotates activations with *logical* axes; the active rule table
maps them to mesh axes.  The table is part of the distributed configuration
the tree autotuner searches over — remapping a logical axis is the TPU-level
analogue of the paper's ``parallelize_thread`` pragma (DESIGN.md §2).

Rules are process-global and installed by the launcher (or a test fixture);
when no rules are installed every ``constrain`` is a no-op, which is what CPU
smoke tests want.
"""

from __future__ import annotations

import contextlib
from typing import Iterable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (str), tuple of mesh axes, or None (replicated)
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": "model",       # sequence-sharded KV cache (decode)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "qk": None,
    "ff": "model",
    "moe_ff": None,
    "experts": "model",      # expert-parallel axis (all_to_all dispatch)
    "fsdp": ("pod", "data"), # ZeRO-3: weight dim sharded over the data axes,
                             # gathered transiently per layer (else the 100B+
                             # configs cannot hold params+grads+opt in HBM)
    "vocab": "model",
    "layers": None,
    "ssm_heads": "model",
    "lru": "model",
    "conv": None,
}

_STATE: dict[str, object] = {"mesh": None, "rules": None}


def install(mesh: Mesh | None, rules: dict[str, object] | None = None) -> None:
    _STATE["mesh"] = mesh
    if mesh is None:
        _STATE["rules"] = None
        return
    rules = dict(DEFAULT_RULES if rules is None else rules)
    # drop mesh axes the mesh doesn't have (single-pod mesh has no "pod")
    axes = set(mesh.axis_names)

    def _filter(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in axes else None
        vv = tuple(a for a in v if a in axes)
        return vv if vv else None

    _STATE["rules"] = {k: _filter(v) for k, v in rules.items()}


def active() -> bool:
    return _STATE["rules"] is not None


def mesh() -> Mesh | None:
    return _STATE["mesh"]


def rules() -> dict[str, object] | None:
    return _STATE["rules"]


@contextlib.contextmanager
def scope(mesh: Mesh | None, rules: dict[str, object] | None = None):
    prev = dict(_STATE)
    install(mesh, rules)
    try:
        yield
    finally:
        _STATE.update(prev)


def spec(*logical: str | None) -> P:
    """PartitionSpec for a rank-len(logical) tensor."""
    r = _STATE["rules"] or {}
    parts = []
    used: set[str] = set()

    def _dedup(v):
        # a mesh axis may appear only once in a PartitionSpec
        if v is None:
            return None
        if isinstance(v, str):
            if v in used:
                return None
            used.add(v)
            return v
        vv = tuple(a for a in v if a not in used)
        used.update(vv)
        return vv if vv else None

    for name in logical:
        parts.append(_dedup(r.get(name)) if name else None)
    return P(*parts)


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint against the installed rules (no-op without)."""
    if not active():
        return x
    m = _STATE["mesh"]
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, spec(*logical)))


def axis_size(logical: str) -> int:
    """Mesh extent a logical axis is sharded over (1 when replicated)."""
    if not active():
        return 1
    r = _STATE["rules"] or {}
    v = r.get(logical)
    if v is None:
        return 1
    m = _STATE["mesh"]
    if isinstance(v, str):
        return m.shape.get(v, 1)
    n = 1
    for a in v:
        n *= m.shape.get(a, 1)
    return n


def named_sharding(*logical: str | None) -> NamedSharding | None:
    if not active():
        return None
    return NamedSharding(_STATE["mesh"], spec(*logical))


def named_sharding_for(shape: tuple[int, ...], *logical: str | None):
    """Like :func:`named_sharding` but drops any axis whose mesh extent does
    not divide the corresponding dim — explicit pjit argument shardings
    require exact divisibility (constraints inside the graph tolerate padding,
    arguments do not)."""
    if not active():
        return None
    m = _STATE["mesh"]
    base = spec(*logical)
    parts = []
    for dim, entry in zip(shape, tuple(base) + (None,) * (len(shape) - len(base))):
        if entry is None:
            parts.append(None)
            continue
        axes_ = (entry,) if isinstance(entry, str) else tuple(entry)
        n = 1
        for a in axes_:
            n *= m.shape.get(a, 1)
        parts.append(entry if (n and dim % n == 0) else None)
    return NamedSharding(m, P(*parts))


def tree_shardings(axes_tree):
    """Map a pytree of logical-axes tuples to NamedShardings (or None)."""
    if not active():
        return None
    return jax.tree.map(
        lambda axes: named_sharding(*axes),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )
