"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2412.19437).

Q and KV are projected through low-rank latents; only the compressed KV latent
(kv_lora_rank + qk_rope_dim per token — 576 floats for V3, independent of the
128 heads) is cached.  Decode uses the *absorbed-weights* form: W_uk is folded
into the query and W_uv into the output so attention runs directly against the
latent cache — the production trick that makes MLA decode memory-lean.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import sharding as sh
from .layers import dense_init, rmsnorm, rope


class MLACache(NamedTuple):
    ckv: jax.Array           # (B, S_max, kv_lora_rank)
    krope: jax.Array         # (B, S_max, qk_rope_dim)
    length: jax.Array        # (B,)


def mla_params_init(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, qr), dt),
        "q_norm": jnp.ones((qr,), dt),
        "wq_b": dense_init(ks[1], (qr, H * (dn + dr)), dt),
        "wkv_a": dense_init(ks[2], (d, kvr + dr), dt),
        "kv_norm": jnp.ones((kvr,), dt),
        "wk_b": dense_init(ks[3], (kvr, H * dn), dt),
        "wv_b": dense_init(ks[4], (kvr, H * dv), dt),
        "wo": dense_init(ks[5], (H * dv, d), dt, scale=1.0 / math.sqrt(H * dv)),
    }


def mla_axes(cfg):
    return {
        "wq_a": ("fsdp", None), "q_norm": (None,),
        "wq_b": ("fsdp", "heads"),
        "wkv_a": ("fsdp", None), "kv_norm": (None,),
        "wk_b": (None, "heads"), "wv_b": (None, "heads"),
        "wo": ("heads", "fsdp"),
    }


def _latents(x, p, cfg, positions):
    """Shared Q latent + KV latent computation."""
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype

    cq = rmsnorm(x @ p["wq_a"].astype(dt), p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"].astype(dt)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv = x @ p["wkv_a"].astype(dt)                    # (B,S,kvr+dr)
    ckv = rmsnorm(kv[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = rope(kv[..., cfg.kv_lora_rank:][:, :, None, :],
                  positions, cfg.rope_theta)[:, :, 0, :]     # shared across heads
    return q_nope, q_rope, ckv, k_rope


def mla_attention(x, p, cfg, positions, *, cache: MLACache | None = None):
    """Returns (y, new_cache).  Absorbed form throughout: scores are computed
    in latent space, so train/prefill and decode share one code path."""
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    dt = x.dtype
    scale = 1.0 / math.sqrt(dn + dr)

    q_nope, q_rope, ckv, k_rope = _latents(x, p, cfg, positions)

    # absorb W_uk into the query: q̃ = q_nope · W_uk → latent space
    wk_b = p["wk_b"].astype(dt).reshape(kvr, H, dn)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wk_b)        # (B,S,H,kvr)
    q_lat = sh.constrain(q_lat, "batch", "seq", "heads", None)

    if cache is None:
        bq = cfg.attn_q_chunk
        if bq and S > bq and S % bq == 0:
            # blockwise attention over query chunks (see layers.attention)
            outs = []
            for qi in range(S // bq):
                sl = slice(qi * bq, (qi + 1) * bq)
                hi = (qi + 1) * bq
                o = _mla_scores_ctx(
                    q_lat[:, sl], q_rope[:, sl], ckv[:, :hi], k_rope[:, :hi],
                    positions[:, None, :hi] <= positions[:, sl][:, :, None],
                    scale, dt)
                outs.append(o)
            ctx_lat = jnp.concatenate(outs, axis=1)
            new_cache = MLACache(ckv=ckv, krope=k_rope,
                                 length=jnp.full((B,), S, jnp.int32))
            wv_b = p["wv_b"].astype(dt).reshape(kvr, H, dv)
            o = jnp.einsum("bshr,rhd->bshd", ctx_lat, wv_b)
            o = o.reshape(B, S, H * dv)
            y = o @ p["wo"].astype(dt)
            return sh.constrain(y, "batch", "seq", "embed"), new_cache
        keys_lat, keys_rope = ckv, k_rope
        qpos = positions[:, :, None]
        kpos = positions[:, None, :]
        mask = kpos <= qpos                                   # (B,S,S)
        new_cache = MLACache(ckv=ckv, krope=k_rope,
                             length=jnp.full((B,), S, jnp.int32))
    else:
        idx = cache.length[0]
        keys_lat = jax.lax.dynamic_update_slice_in_dim(
            cache.ckv, ckv.astype(cache.ckv.dtype), idx, axis=1)
        keys_rope = jax.lax.dynamic_update_slice_in_dim(
            cache.krope, k_rope.astype(cache.krope.dtype), idx, axis=1)
        keys_lat = sh.constrain(keys_lat, "batch", "kv_seq", None)
        keys_rope = sh.constrain(keys_rope, "batch", "kv_seq", None)
        Smax = keys_lat.shape[1]
        mask = (jnp.arange(Smax)[None, None, :] <= idx)       # (1,1,Smax)
        new_cache = MLACache(ckv=keys_lat, krope=keys_rope,
                             length=cache.length + S)

    ctx_lat = _mla_scores_ctx(q_lat, q_rope, keys_lat, keys_rope, mask,
                              scale, dt)
    wv_b = p["wv_b"].astype(dt).reshape(kvr, H, dv)
    o = jnp.einsum("bshr,rhd->bshd", ctx_lat, wv_b)           # absorb W_uv
    o = o.reshape(B, S, H * dv)
    y = o @ p["wo"].astype(dt)
    return sh.constrain(y, "batch", "seq", "embed"), new_cache


def _mla_scores_ctx(q_lat, q_rope, keys_lat, keys_rope, mask, scale, dt):
    """Latent-space attention: scores in compute dtype with stable row stats
    (flash-style numerics; an f32 (B,H,S,S) tensor would not fit HBM at 4k+).
    Returns the attended latent context (B, Sq, H, kvr)."""
    B = q_lat.shape[0]
    s = jnp.einsum("bshr,btr->bhst", q_lat, keys_lat)
    s = s + jnp.einsum("bshd,btd->bhst", q_rope.astype(dt), keys_rope)
    s = s * jnp.asarray(scale, s.dtype)
    m = jnp.broadcast_to(mask, (B,) + mask.shape[1:])
    neg = jnp.asarray(-3e38 if s.dtype == jnp.float32 else -3e4, s.dtype)
    s = jnp.where(m[:, None, ...], s, neg)
    smax = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    prob = jnp.exp(s - smax)
    # bf16 probs end-to-end (see layers._sdpa for the rationale)
    l = jnp.sum(prob, axis=-1, keepdims=True)       # (B,H,Sq,1)
    ctx = jnp.einsum("bhst,btr->bshr", prob, keys_lat)
    return (ctx / jnp.maximum(jnp.transpose(l, (0, 2, 1, 3)), 1e-6)).astype(dt)
