"""Distributed example: lower + compile one production cell on the 512-chip
multi-pod mesh and print its roofline terms — the per-cell core of
``repro.launch.dryrun`` as a minimal script.

    PYTHONPATH=src python examples/distributed_dryrun.py [--arch glm4_9b]
"""

# must precede any jax import (device count locks at first init)
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="internlm2_1_8b")
    ap.add_argument("--shape", type=str, default="train_4k")
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell

    rec = lower_cell(args.arch, args.shape, "multi", verbose=True)
    if rec.get("skip"):
        print("cell skipped:", rec["skip"])
        return
    print("\nroofline record:")
    for k in ("chips", "compute_s", "memory_s", "collective_s", "dominant",
              "useful_flops_fraction", "roofline_fraction"):
        print(f"  {k:24s} {rec[k]}")


if __name__ == "__main__":
    main()
