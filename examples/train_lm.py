"""End-to-end driver: train an LM with checkpointing, a simulated mid-run
node failure, an automatic restart, and a straggler watchdog — the full
production loop at laptop scale.

Default config (~12M params, 60 steps) finishes in a few minutes on this
1-core container; ``--hundred-m --steps 300`` is the full ~100M/300-step run
for real hardware.

    PYTHONPATH=src python examples/train_lm.py [--steps 60] [--hundred-m]
"""

import argparse
import dataclasses
import shutil

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig
from repro.models.model import count_params_from_specs
from repro.optim import OptimizerConfig
from repro.train.fault_tolerance import FailureInjector, run_with_restarts
from repro.train.train_loop import LoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_train_lm")
    ap.add_argument("--fail-at", type=int, default=30,
                    help="inject a simulated node failure at this step")
    ap.add_argument("--hundred-m", action="store_true",
                    help="full ~100M-param config (for real hardware)")
    args = ap.parse_args()

    if args.hundred_m:
        # ~100M params: internlm2 family at width 768 / 12 layers
        cfg = dataclasses.replace(
            get_config("internlm2_1_8b"),
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
            d_ff=2048, vocab_size=32000, dtype="float32",
            param_dtype="float32", scan_layers=True, remat="none")
    else:
        cfg = dataclasses.replace(
            get_config("internlm2_1_8b"),
            n_layers=6, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
            d_ff=768, vocab_size=32000, dtype="float32",
            param_dtype="float32", scan_layers=True, remat="none")
    print(f"model: {cfg.name} variant, params="
          f"{count_params_from_specs(cfg)/1e6:.1f}M")

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    opt = OptimizerConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    loop = LoopConfig(total_steps=args.steps, log_every=10,
                      ckpt_every=max(10, args.steps // 4),
                      ckpt_dir=args.ckpt_dir)
    seq, gb = (256, 8) if args.hundred_m else (128, 4)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=gb)

    injector = FailureInjector(fail_at_steps=(args.fail_at,))

    def attempt(_start):
        return train(cfg, opt, loop, data, injector=injector)

    res, restarts = run_with_restarts(
        attempt, max_restarts=2,
        on_restart=lambda n, e: print(f"  !! {e} — restarting ({n})"))

    print(f"\nfinished at step {res.last_step} with {restarts} restart(s); "
          f"restored from step {res.restored_from}")
    print("loss curve:")
    for s, l in res.losses:
        print(f"  step {s:4d}: {l:.4f}")
    if res.straggler_flags:
        print("straggler-flagged steps:", res.straggler_flags)
    first, last = res.losses[0][1], res.losses[-1][1]
    print(f"\nloss {first:.3f} → {last:.3f} "
          f"({'learning ✓' if last < first - 0.3 else 'check config'})")


if __name__ == "__main__":
    main()
