"""Fleet quickstart: tuning-as-a-service on one machine (ROADMAP item 1).

Boots the whole fleet in a single process — a dispatcher (door lint, FIFO
queue, federation merge daemon) with its HTTP server on an ephemeral port,
two workers running jobs through the unchanged ``TuningSession`` stack —
then submits a ``TuningSpec``, follows the experiment stream, and
re-submits the identical spec to show it served from the federated cache
with zero backend dispatches.

    PYTHONPATH=src python examples/fleet_quickstart.py

In a real deployment each piece is its own process/host:

    python -m repro.fleet.server --port 8757 --spool /var/tune/spool
    python -m repro.fleet.worker --connect dispatcher:8757   # per host
    python -m repro.fleet.client submit spec.json --follow
"""

import tempfile
import threading

from repro.fleet import Dispatcher, FleetHTTPServer, FleetWorker
from repro.fleet.client import follow, submit

SPEC = {
    "workload": "gemm", "strategy": "greedy", "budget": 40,
    "backend": "costmodel",
    "space_args": {"tile_sizes": [16, 64, 256], "max_transformations": 3},
    # no "store": the fleet's federation policy kicks in — the worker
    # primes a local store from GET /store and uploads it back on finish
}


def main():
    with tempfile.TemporaryDirectory(prefix="fleet_quickstart_") as tmp:
        dispatcher = Dispatcher(spool_dir=f"{tmp}/spool", lint_samples=100,
                                federation_interval_s=0.5)
        server = FleetHTTPServer(dispatcher, ("127.0.0.1", 0))
        threading.Thread(target=server.serve_forever, daemon=True).start()
        print(f"dispatcher listening on 127.0.0.1:{server.port}")

        workers = [FleetWorker("127.0.0.1", server.port, name=f"w{i}",
                               workdir=f"{tmp}/w{i}") for i in (1, 2)]
        for w in workers:
            w.register()

        job = submit("127.0.0.1", server.port, dict(SPEC))
        print(f"submitted {job['job_id']}: lint sampled "
              f"{job['lint']['samples']} configs, "
              f"{job['lint']['infeasible_fraction']:.0%} infeasible")

        workers[0].run_one()                # a worker picks the job up
        for ev in follow("127.0.0.1", server.port, job["job_id"]):
            if ev["event"] == "experiment" and ev["number"] % 10 == 0:
                print(f"  exp #{ev['number']:3d}  {ev['status']:14s} "
                      f"time={ev.get('time_s')}")
            elif ev["event"] == "done":
                best = ev["result"]["best"]
                print(f"done: best time {best['time_s']:.3f}s at "
                      f"experiment #{best['number']}")

        # the identical spec again — served from the federated cache
        job2 = submit("127.0.0.1", server.port, dict(SPEC))
        workers[1].run_one()                # the *other* worker, warm
        st = dispatcher.job_status(job2["job_id"])
        cache = st["result"]["cache"]
        print(f"re-submitted as {job2['job_id']}: preloaded "
              f"{cache['preloaded']} records, {cache['hits']} cache hits — "
              f"best {st['result']['best']['time_s']:.3f}s "
              f"(same answer, no re-measurement)")

        server.shutdown()
        server.server_close()


if __name__ == "__main__":
    main()
