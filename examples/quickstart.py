"""Quickstart: the paper's search space in five minutes.

Builds the gemm loop nest, derives children exactly as §III describes, runs
the greedy autotuner (paper §IV-C) on the Xeon-8180M cost model with and
without parallelization, and prints the local-minimum phenomenon of §VI.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (GEMM, Configuration, CostModelBackend, Parallelize,
                        SearchSpace, Tile, TuningSession)


def main():
    nest = GEMM.nest()
    print("loop nest:", nest.pretty())

    space = SearchSpace(root=nest)
    counts = space.count_children_by_kind(Configuration())
    print(f"children of the baseline: {counts}  "
          f"(paper §V: 190 tilings, 5 interchanges, 3 parallelizations)")

    # one concrete configuration, rendered the paper's way
    cfg = (Configuration()
           .child(Tile(loops=("i", "j", "k"), sizes=(64, 1024, 64)))
           .child(Parallelize(loop="i1")))
    print("\na multi-step configuration:")
    print(cfg.pragmas())

    # one TuningSession owns measurement for every strategy; strategies are
    # registry names (greedy / mcts / beam / random / ei)
    session = TuningSession(CostModelBackend())
    print("\n--- greedy, parallelize enabled (paper Fig. 6) ---")
    log = session.tune(GEMM, space, strategy="greedy", budget=300)
    b = log.best()
    print(f"baseline {log.baseline.result.time_s:.2f}s → best "
          f"{b.result.time_s:.3f}s at experiment #{b.number}")
    print(b.pragmas)
    print("note: the first transformation is parallelize(outermost) — the "
          "greedy local minimum of §VI-A.")

    print("\n--- MCTS (paper §VIII future work) ---")
    mlog = session.tune(GEMM, SearchSpace(root=nest), strategy="mcts",
                        budget=600, seed=1)
    mb = mlog.best()
    print(f"best {mb.result.time_s:.3f}s at depth {len(mb.config)}:")
    print(mb.pragmas)

    # measurements persist across runs in a pluggable store — pass the URI
    # form (jsonl://... for the append-only log, sqlite://... for the
    # indexed backend) to TuningSession(store=...); a re-tune replays every
    # stored structure for free, and surrogate_scope="cross_workload" lets a
    # new kernel's learned surrogate warm-start from the other kernels'
    # history.  (Constructing ResultStore(path) directly is the deprecated
    # old spelling — it assumes JSONL and emits a DeprecationWarning.)
    import tempfile

    from repro.core import ResultStore
    with tempfile.TemporaryDirectory() as tmp:
        store_uri = f"sqlite://{tmp}/quickstart.db"
        print(f"\n--- persistent store warm start ({store_uri}) ---")
        warm_session = TuningSession(CostModelBackend(), store=store_uri)
        warm_session.tune(GEMM, SearchSpace(root=nest), budget=200)
        relog = warm_session.tune(GEMM, SearchSpace(root=nest), budget=200)
        print(f"re-tune replayed {relog.cache['preloaded']} stored "
              f"structures with {relog.cache['misses']} backend calls")
        # release the shared connection before the tempdir is deleted
        ResultStore.drop_shared(store_uri)


if __name__ == "__main__":
    main()
