"""Autotune the Pallas gemm kernel's BlockSpec tiles with REAL execution.

Uses the wallclock backend (XLA:CPU at a reduced problem size — cache effects
are physically real on this machine) to rank tile configurations, verifies the
winning schedule's Pallas kernel against the jnp oracle in interpret mode, and
prints the pragma form + the block config you would pass to
``repro.kernels.ops.matmul`` on a TPU.

    PYTHONPATH=src python examples/autotune_gemm.py
    PYTHONPATH=src python examples/autotune_gemm.py --store sqlite:///tmp/tune.db

``--store`` attaches the persistent measurement store in its URI form —
``jsonl://path`` (the append-only log) or ``sqlite://path`` (indexed, for
long-lived stores); a bare path resolves by suffix.  Re-running with the
same store replays every previously measured structure with zero wallclock
spend.  The old spelling — constructing ``ResultStore(path)`` directly and
assuming JSONL — still works but emits a ``DeprecationWarning``; pass the
URI (or path) straight to ``TuningSession(store=...)`` instead.
"""

import argparse

import numpy as np

from repro.core import (GEMM, Configuration, PallasBackend, SearchSpace,
                        TuningSession, WallclockBackend)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--store", default=None, metavar="URI",
        help="persistent result store (jsonl://... / sqlite://... / path); "
             "re-runs warm-start from it instead of re-measuring")
    args = ap.parse_args()
    # tile/interchange only: wallclock on one CPU core can't measure
    # thread-parallelization (the cost model handles that; see quickstart)
    space = SearchSpace(
        root=GEMM.nest(),
        enable_parallelize=False,
        tile_sizes=(16, 32, 64, 128),
        max_transformations=2,
    )
    be = WallclockBackend(scale=0.12, reps=2)
    print("tuning gemm tiles on real XLA:CPU wallclock "
          f"(scale=0.12 → extents ≈ {GEMM.scaled(0.12).extents}) ...")
    # surrogate="analytic": under a tight wallclock budget, spend the
    # compile+run experiments on the cost model's top-ranked children first
    # (the old boolean alias for this is deprecated)
    # store=None (no flag) still defers to the CC_RESULT_STORE env default;
    # an explicit --store always wins over it
    session = TuningSession(be, surrogate="analytic", store=args.store)
    log = session.tune(GEMM, space, strategy="greedy", budget=60)
    if args.store and log.cache.get("preloaded"):
        print(f"(warm start: {log.cache['preloaded']} structures replayed "
              f"from {args.store})")
    best = log.best()
    print(f"\nbaseline (XLA default einsum): "
          f"{log.baseline.result.time_s*1e3:.1f} ms")
    print(f"best: {best.result.time_s*1e3:.1f} ms at experiment #{best.number}")
    print(best.pragmas or "(baseline wins — XLA's einsum is well tiled "
          "already; the pragmas matter on the TPU path)")

    # correctness gate: the same schedule as a Pallas kernel vs the oracle
    pb = PallasBackend(verify=True)
    res = pb.evaluate(GEMM, best.config)
    print(f"\npallas interpret-mode verification: {res.status} "
          f"(tpu-v5e cost-model projection {res.time_s:.4f}s)"
          if res.ok else f"pallas check: {res.status}: {res.note}")


if __name__ == "__main__":
    main()
