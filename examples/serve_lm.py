"""Serving example: batched prefill + KV-cache decode through the engine,
with a cache-correctness cross-check against uncached prefill.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("glm4_9b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=256)

    reqs = [
        Request(prompt=[1, 2, 3, 4, 5], max_new_tokens=16),
        Request(prompt=[42, 17], max_new_tokens=16),
        Request(prompt=[7, 7, 7, 7, 7, 7, 7], max_new_tokens=16),
        Request(prompt=[100, 200, 300], max_new_tokens=16),
    ]
    t0 = time.perf_counter()
    out = eng.generate(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in out)
    print(f"generated {total} tokens for {len(reqs)} requests "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s, reduced config on CPU)")
    for i, r in enumerate(out):
        print(f"  req{i} prompt={r.prompt} → {r.out}")

    # cross-check the longest request (no left-padding) against uncached
    # greedy decoding.  NOTE: shorter requests in a mixed-length wave attend
    # to their left-pad tokens — a known engine limitation; production would
    # use per-sequence masks / paged attention (DESIGN.md §8).
    longest = max(range(len(reqs)), key=lambda i: len(out[i].prompt))
    seq = list(out[longest].prompt)
    want = []
    for _ in range(4):
        logits, _ = m.prefill(params, {"tokens": jnp.asarray([seq], jnp.int32)})
        nxt = int(jnp.argmax(logits[0, -1]))
        want.append(nxt)
        seq.append(nxt)
    ok = out[longest].out[:4] == want
    print(f"\nKV-cache correctness (unpadded request) vs uncached prefill: "
          f"{'MATCH ✓' if ok else f'MISMATCH {out[longest].out[:4]} vs {want}'}")


if __name__ == "__main__":
    main()
